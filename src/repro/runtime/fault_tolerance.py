"""Fault-tolerance runtime: checkpoint/restart loop, straggler monitor,
failure injection for tests.

At 1000+ nodes the mean time between node failures drops below the length
of a training run; the loop here implements the standard contract:
  * every step is resumable: (params, dsg, opt, data cursor) all live in
    the checkpoint; the data pipeline is a pure function of step, so
    replaying from step k is bit-exact;
  * failures (device loss, preemption, host OOM) surface as exceptions
    from the step call -> restore from the newest complete checkpoint and
    continue (bounded retries per step to avoid crash loops);
  * a straggler monitor records per-step wall time and flags outliers
    (> factor x rolling median) — on a real fleet this feeds the scheduler
    (hot-swap of the slow host); here it logs and counts, and tests verify
    detection on injected delays.
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

log = logging.getLogger("repro.runtime")


class StragglerMonitor:
    def __init__(self, window: int = 32, factor: float = 1.5):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.flagged = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            xs = sorted(self.times)
            median = xs[len(xs) // 2]
            if seconds > self.factor * median:
                self.flagged.append((step, seconds, median))
                is_straggler = True
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, seconds, median)
        self.times.append(seconds)
        return is_straggler


@dataclass
class FaultInjector:
    """Deterministic failure injection for tests: raises at given steps."""
    fail_at: tuple = ()
    exc: type = RuntimeError
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise self.exc(f"injected failure at step {step}")


def run_with_restarts(*, step_fn: Callable, state, make_batch: Callable,
                      ckpt, total_steps: int, start_step: int = 0,
                      ckpt_every: int = 20, max_retries: int = 3,
                      injector: Optional[FaultInjector] = None,
                      on_step: Optional[Callable] = None,
                      monitor: Optional[StragglerMonitor] = None):
    """Fault-tolerant training loop.

    step_fn(state, batch) -> (state, metrics).  ckpt: CheckpointManager.
    Restores and replays on any exception, up to max_retries per step.
    Returns (state, history)."""
    monitor = monitor or StragglerMonitor()
    history = []
    step = start_step
    retries = 0
    while step < total_steps:
        try:
            t0 = time.time()
            if injector is not None:
                injector.maybe_fail(step)
            batch = make_batch(step)
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            monitor.record(step, dt)
            history.append({"step": step, "seconds": dt, **{
                k: float(v) for k, v in metrics.items()}})
            if on_step is not None:
                on_step(step, state, metrics)
            if ckpt is not None and (step + 1) % ckpt_every == 0:
                ckpt.save_async(step + 1, state, meta={"step": step + 1})
            step += 1
            retries = 0
        except Exception as e:                      # noqa: BLE001
            retries += 1
            log.error("step %d failed (%s); retry %d/%d", step, e,
                      retries, max_retries)
            if retries > max_retries:
                raise
            if ckpt is not None:
                restored, rstep, _ = ckpt.restore(state)
                if restored is not None:
                    state = restored
                    step = rstep
                    log.info("restored from checkpoint at step %d", rstep)
    if ckpt is not None:
        ckpt.wait()
    return state, history
