"""Fault-tolerance runtime: checkpoint/restart loop, straggler monitor,
failure injection for tests, and the serving-grade chaos injector.

At 1000+ nodes the mean time between node failures drops below the length
of a training run; the loop here implements the standard contract:
  * every step is resumable: (params, dsg, opt, data cursor) all live in
    the checkpoint; the data pipeline is a pure function of step, so
    replaying from step k is bit-exact;
  * failures (device loss, preemption, host OOM) surface as exceptions
    from the step call -> restore from the newest complete checkpoint and
    continue (bounded retries per step to avoid crash loops);
  * a straggler monitor records per-step wall time and flags outliers
    (> factor x rolling median) — on a real fleet this feeds the scheduler
    (hot-swap of the slow host); here it logs and counts, and tests verify
    detection on injected delays.

The serving side applies the same replay contract to replica death
instead of host preemption: `ServingFaultInjector` deterministically
kills / delays / poisons a serving replica at a chosen engine step, and
the Router's failover (serving/router.py, docs/fault_tolerance.md)
replays the reclaimed requests from their prompts on healthy replicas —
bit-identical at temperature 0 because each replica is solo-
deterministic.  benchmarks/bench_router_faults.py gates exactly that.
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

log = logging.getLogger("repro.runtime")


class StragglerMonitor:
    def __init__(self, window: int = 32, factor: float = 1.5):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.flagged = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            xs = sorted(self.times)
            median = xs[len(xs) // 2]
            if seconds > self.factor * median:
                self.flagged.append((step, seconds, median))
                is_straggler = True
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, seconds, median)
        self.times.append(seconds)
        return is_straggler


@dataclass
class FaultInjector:
    """Deterministic failure injection for tests: raises at given steps."""
    fail_at: tuple = ()
    exc: type = RuntimeError
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise self.exc(f"injected failure at step {step}")


# -- serving chaos harness ---------------------------------------------------

#: Token value a "poison" fault writes over a lane's last emitted token —
#: obviously out-of-vocab so a poisoned stream that survives failover
#: (instead of being replayed from the prompt) cannot pass a bitwise
#: stream-equality gate by accident.
POISON_TOKEN = -7

FAULT_KINDS = ("kill", "delay", "poison")


class InjectedFault(RuntimeError):
    """The injected replica-crash exception: what a real device loss /
    worker OOM surfaces as, minus the flakiness."""


@dataclass(frozen=True)
class ReplicaFault:
    """One deterministic serving fault: when replica `replica`'s engine
    reaches step `step`, do `kind`.  "Reaches" means the first step
    boundary whose counter is AT OR PAST `step`: with a fused decode
    chunk (ServingEngine decode_chunk > 1) the counter advances by up to
    a whole chunk per boundary, so an exact-match key landing mid-chunk
    would never fire — the fault instead lands on the next chunk
    boundary, which is also the only place the engine can contain it.

      kill   — raise InjectedFault at the step boundary, before the
               step's tokens land (the clean worker-death case);
      delay  — sleep `delay_s` inside the step (a straggler; trips the
               router's stall timeout when one is configured);
      poison — overwrite the last emitted token of every resident lane
               with POISON_TOKEN, then raise (the dirty death: failover
               must discard the partial output and replay from the
               prompt, or the corruption survives into the stream).
    """
    replica: int
    step: int
    kind: str = "kill"
    delay_s: float = 0.05

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.replica < 0 or self.step < 0:
            raise ValueError(f"replica/step must be >= 0 "
                             f"(got {self.replica}/{self.step})")


class ServingFaultInjector:
    """Serving-grade FaultInjector: deterministic faults keyed on
    (replica index, engine step), each firing exactly ONCE even across
    replica restarts or benchmark repeats (until `reset()` re-arms).

    `attach(engines)` stamps each engine's `replica_index` and installs
    the injector as its `fault_injector`; `ServingEngine.begin_step()`
    calls `on_step(engine)` at every step boundary.  Attach AFTER warmup:
    warmup resets step counters, so a fault keyed on an early step would
    otherwise fire inside the compile pass."""

    def __init__(self, faults: Sequence[ReplicaFault]):
        self.faults: List[ReplicaFault] = [
            f if isinstance(f, ReplicaFault) else ReplicaFault(*f)
            for f in faults]
        self._fired: set = set()
        self.log: List[dict] = []      # faults that actually fired

    def attach(self, engines) -> None:
        for r, eng in enumerate(engines):
            eng.replica_index = r
            eng.fault_injector = self

    def detach(self, engines) -> None:
        for eng in engines:
            if eng.fault_injector is self:
                eng.fault_injector = None

    def reset(self) -> None:
        """Re-arm every fault (benchmark repeats)."""
        self._fired.clear()
        self.log.clear()

    def on_step(self, eng) -> None:
        for k, f in enumerate(self.faults):
            # >= (not ==): a chunked engine's counter jumps by up to
            # decode_chunk per boundary, so a mid-chunk key fires at the
            # first boundary past it instead of being skipped forever
            if (k in self._fired or f.replica != eng.replica_index
                    or eng.steps < f.step):
                continue
            self._fired.add(k)
            self.log.append({"replica": f.replica, "step": f.step,
                             "kind": f.kind})
            if f.kind == "delay":
                time.sleep(f.delay_s)
                continue
            if f.kind == "poison":
                for slot in eng.slots:
                    if slot.req is not None and slot.req.output:
                        slot.req.output[-1] = POISON_TOKEN
            raise InjectedFault(
                f"injected {f.kind} at replica {f.replica} "
                f"step {f.step}")


def run_with_restarts(*, step_fn: Callable, state, make_batch: Callable,
                      ckpt, total_steps: int, start_step: int = 0,
                      ckpt_every: int = 20, max_retries: int = 3,
                      injector: Optional[FaultInjector] = None,
                      on_step: Optional[Callable] = None,
                      monitor: Optional[StragglerMonitor] = None):
    """Fault-tolerant training loop.

    step_fn(state, batch) -> (state, metrics).  ckpt: CheckpointManager.
    Restores and replays on any exception, up to max_retries per step.
    Returns (state, history)."""
    monitor = monitor or StragglerMonitor()
    history = []
    step = start_step
    retries = 0
    while step < total_steps:
        try:
            t0 = time.perf_counter()
            if injector is not None:
                injector.maybe_fail(step)
            batch = make_batch(step)
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            monitor.record(step, dt)
            history.append({"step": step, "seconds": dt, **{
                k: float(v) for k, v in metrics.items()}})
            if on_step is not None:
                on_step(step, state, metrics)
            if ckpt is not None and (step + 1) % ckpt_every == 0:
                ckpt.save_async(step + 1, state, meta={"step": step + 1})
            step += 1
            retries = 0
        except Exception as e:                      # noqa: BLE001
            retries += 1
            log.error("step %d failed (%s); retry %d/%d", step, e,
                      retries, max_retries)
            if retries > max_retries:
                raise
            if ckpt is not None:
                restored, rstep, _ = ckpt.restore(state)
                if restored is not None:
                    state = restored
                    step = rstep
                    log.info("restored from checkpoint at step %d", rstep)
    if ckpt is not None:
        ckpt.wait()
    return state, history
