"""Elastic scaling: re-plan the mesh after node loss / fleet resize.

Policy (DESIGN.md §6): the 'model' axis is load-bearing (weights are
sharded across it — losing a model shard loses state), so elasticity acts
on the data axes: after losing nodes we shrink 'data' (and/or 'pod') to
the largest supported configuration, re-shard the carried state onto the
new mesh, and scale the per-step token budget accordingly (global batch
follows the data axis unless the caller re-pads).

This module is pure planning + re-sharding; the fleet events come from the
scheduler (tests inject them).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import NamedSharding

from repro.launch.mesh import make_elastic_mesh


@dataclass(frozen=True)
class ElasticPlan:
    n_devices: int
    data: int
    model: int
    dropped: int

    @property
    def scale(self) -> float:
        return self.data * self.model / (self.data * self.model + self.dropped)


def plan_after_loss(available_devices: int, model: int = 16,
                    prev_data: Optional[int] = None) -> ElasticPlan:
    """Largest (data, model) mesh with the model axis intact."""
    data = available_devices // model
    if data < 1:
        raise RuntimeError(
            f"cannot keep model={model} with {available_devices} devices")
    # prefer powers of two on the data axis (collective efficiency)
    d = 1
    while d * 2 <= data:
        d *= 2
    used = d * model
    return ElasticPlan(n_devices=used, data=d, model=model,
                       dropped=available_devices - used)


def remesh_state(state, old_specs, plan: ElasticPlan):
    """Re-shard a state pytree onto the degraded mesh.  Specs are reused:
    they reference axis NAMES, which the new mesh preserves."""
    mesh = make_elastic_mesh(plan.n_devices, plan.model)

    def move(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return mesh, jax.tree.map(move, state, old_specs,
                              is_leaf=lambda x: x is None)
