"""JAX version-compatibility shims.

The repo targets the moving jax_pallas toolchain, but the API surface for
explicit meshes and shard_map has drifted across JAX releases:

  * ``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
    ``jax.make_mesh`` only exist on newer JAX (>= 0.5 era); on 0.4.x the
    mesh is implicitly all-Auto.
  * ``jax.shard_map`` was promoted out of ``jax.experimental.shard_map``
    and its replication-check kwarg renamed ``check_rep`` -> ``check_vma``.

Everything in the repo goes through these two wrappers instead of calling
the drifting APIs directly, so a single JAX pin change never fans out.
"""
from __future__ import annotations

import jax

try:  # newer JAX: explicit axis types
    from jax.sharding import AxisType as _AxisType
except ImportError:  # 0.4.x: meshes are implicitly Auto
    _AxisType = None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with all-Auto axis types where supported."""
    if _AxisType is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(_AxisType.Auto,) * len(axis_names),
                                 devices=devices)
        except TypeError:
            pass  # AxisType exists but make_mesh predates axis_types=
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map with replication/VMA checking disabled, any JAX version.

    All call sites in this repo run with checking off (the collectives are
    validated by numeric tests against sequential references instead).
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # promoted API but pre-rename kwarg
            try:
                return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_rep=False)
            except TypeError:  # kwarg gone entirely
                return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
