"""Deterministic, shard-aware synthetic data pipeline.

No datasets ship in this container, so the pipeline generates a
deterministic token stream: batch(step, host) is a pure function — every
host computes only its slice (as a real multi-host input pipeline must),
restarts reproduce the same stream (checkpoint/resume safe), and the
labels are next-token shifts of a structured sequence (a noisy periodic
language) so models can actually reduce loss on it.

For language-model realism the stream mixes: (i) a vocabulary-walk process
with long-range repetition (so attention/recurrence has something to use),
and (ii) uniform noise tokens at a fixed rate.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


def _gen_tokens(rng: np.random.Generator, batch: int, seq: int,
                vocab: int, noise: float = 0.1) -> np.ndarray:
    period = rng.integers(8, 64)
    base = rng.integers(0, vocab, size=(batch, period))
    reps = seq // period + 2
    toks = np.tile(base, (1, reps))[:, :seq + 1]
    drift = rng.integers(0, vocab, size=(batch, seq + 1))
    mask = rng.random((batch, seq + 1)) < noise
    toks = np.where(mask, drift, toks)
    return toks.astype(np.int32)


def batch_at(step: int, *, global_batch: int, seq_len: int, vocab: int,
             host_index: int = 0, host_count: int = 1, seed: int = 17,
             extras: Optional[dict] = None) -> dict:
    """The batch for `step`, sliced for this host.  Pure & deterministic."""
    assert global_batch % host_count == 0
    local = global_batch // host_count
    rng = np.random.default_rng((seed, step, host_index))
    toks = _gen_tokens(rng, local, seq_len, vocab)
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if extras:
        for name, shape in extras.items():
            out[name] = jnp.asarray(
                rng.standard_normal((local,) + shape), dtype=jnp.float32)
    return out


def stream(start_step: int = 0, **kw) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(step, **kw)
        step += 1


def make_global_batch(step: int, mesh, batch_spec, **kw) -> dict:
    """Assemble a sharded global batch with make_array_from_callback —
    each host materializes only its addressable shards (the multi-host
    input path; on single-host it degenerates to a device_put)."""
    from jax.sharding import NamedSharding

    host_batch = batch_at(step, **kw)

    def globalize(x, spec):
        sharding = NamedSharding(mesh, spec)
        gshape = x.shape

        def cb(index):
            return np.asarray(x[index])

        return jax.make_array_from_callback(gshape, sharding, cb)

    return jax.tree.map(globalize, host_batch, batch_spec)
