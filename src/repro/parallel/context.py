"""Trace-time parallel context.

The launcher (or dry-run driver) installs the mesh before tracing a step
function; model code consults the context to place sharding constraints.
Constraints bake into the traced computation, so the context only needs to
be set around trace time (jit.lower / first call).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import NamedTuple, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import Axes, axes_for_mesh, model_shards


class ParallelCtx(NamedTuple):
    mesh: Mesh
    ax: Axes
    n_model: int


_CTX: Optional[ParallelCtx] = None


def current() -> Optional[ParallelCtx]:
    return _CTX


@contextmanager
def use_mesh(mesh: Optional[Mesh], batch_shardable: bool = True):
    """Install a parallel context.  batch_shardable=False drops the batch
    axes from activation constraints (e.g. long_500k with global_batch=1,
    which cannot divide the data axes — a model-parallel-only workload)."""
    global _CTX
    prev = _CTX
    if mesh is not None:
        ax = axes_for_mesh(mesh)
        if not batch_shardable:
            ax = Axes(batch=None, model=ax.model)
        _CTX = ParallelCtx(mesh, ax, model_shards(mesh))
    else:
        _CTX = None
    try:
        yield _CTX
    finally:
        _CTX = prev


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the active mesh; no-op outside a
    parallel context or on a 1-device mesh."""
    ctx = _CTX
    if ctx is None or ctx.mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def batch_axes():
    ctx = _CTX
    return ctx.ax.batch if ctx else None


def resolve_attn_shard(mode: str, n_heads: int) -> str:
    """'auto' -> 'head' when heads divide the model axis, else 'seq'."""
    ctx = _CTX
    if ctx is None or ctx.n_model == 1:
        return "none"
    if mode != "auto":
        return mode
    return "head" if n_heads % ctx.n_model == 0 else "seq"


def divisible(n: int) -> bool:
    ctx = _CTX
    return ctx is not None and ctx.n_model > 1 and n % ctx.n_model == 0
