"""GPipe-style pipeline parallelism over a 'pipe' mesh axis (shard_map +
collective_permute).

Layers are grouped into n_stages contiguous stages; stage s lives on pipe
rank s (stage-stacked params sharded over the axis).  Microbatches enter
stage 0 one tick at a time and flow through the ring: at every tick each
rank applies its stage and ppermutes the activation to rank+1.  After
n_micro + n_stages - 1 ticks all microbatches have drained; the bubble
fraction is (n_stages - 1) / (n_micro + n_stages - 1) — the standard GPipe
trade-off, amortized by more microbatches.

This is the composable PP building block (used standalone or as an extra
mesh dimension ("pipe","data","model")); tests validate numerics against
the sequential reference on a multi-device host mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(stage_fn: Callable, stage_params, x_micro: jax.Array,
                     mesh: Mesh, axis: str = "pipe") -> jax.Array:
    """Run microbatches through the pipeline.

    stage_fn(params_for_stage, x) -> y  (same shape as x)
    stage_params: pytree with leading dim n_stages on every leaf
    x_micro: (n_micro, micro_batch, ...) microbatch stack
    Returns (n_micro, micro_batch, ...) outputs (from the last stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(params, xs):
        # params: (1, ...) local stage slice; xs: (n_micro, Bm, ...)
        local = jax.tree.map(lambda a: a[0], params)
        rank = jax.lax.axis_index(axis)
        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros((n_ticks,) + xs.shape[1:], xs.dtype)

        def tick(t, carry):
            state, outs = carry
            feed = xs[jnp.minimum(t, n_micro - 1)]
            cur = jnp.where(rank == 0,
                            jnp.where(t < n_micro, feed,
                                      jnp.zeros_like(feed)),
                            state)
            y = stage_fn(local, cur)
            # last stage's result for this tick (zeros elsewhere)
            outs = outs.at[t].set(
                jnp.where(rank == n_stages - 1, y, jnp.zeros_like(y)))
            state = jax.lax.ppermute(y, axis, perm)
            return state, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (state, outs))
        # only the last stage holds real outputs; sum-over-axis broadcasts
        outs = jax.lax.psum(outs, axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    outs = shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
    )(stage_params, x_micro)
    # microbatch m exits the last stage at tick m + n_stages - 1
    return outs[n_stages - 1:]


def sequential_reference(stage_fn: Callable, stage_params,
                         x_micro: jax.Array) -> jax.Array:
    """Ground truth: apply all stages in order to each microbatch."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def run_one(x):
        for s in range(n_stages):
            local = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(local, x)
        return x

    return jax.vmap(run_one)(x_micro)
