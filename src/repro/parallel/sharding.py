"""Logical->physical sharding rules for the (pod, data, model) meshes.

Axis conventions (DESIGN.md §6):
  batch axes  — ('pod', 'data') on the multi-pod mesh, ('data',) single-pod.
  model axis  — 'model': TP for attention heads / FFN columns / vocab,
                EP for MoE experts, SP for long-context KV sequence.

All spec builders take an `Axes` so the same model code lowers on either
mesh (and on a trivial 1-device mesh for smoke tests, where specs are
ignored by jit on a single device).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Axes(NamedTuple):
    batch: Union[Tuple[str, ...], None]   # e.g. ('pod', 'data') or ('data',)
    model: Optional[str]                  # 'model' or None


def axes_for_mesh(mesh: Mesh) -> Axes:
    names = mesh.axis_names
    batch = tuple(n for n in ("pod", "data") if n in names) or None
    model = "model" if "model" in names else None
    return Axes(batch=batch, model=model)


def replica_mesh(n_replicas: int, devices=None) -> Mesh:
    """1-axis `replicas` mesh for data-parallel serving replica groups.

    The sharded replica executor (serving/parallel_exec.py) stacks
    per-replica decode operands and KV caches along a leading replica
    axis and lays that axis over this mesh, so each replica's slice
    lives — and its step computes — on its own device.  `n_replicas`
    must divide the device count; by default the first `n_replicas`
    local devices are used.
    """
    devs = list(devices if devices is not None else jax.local_devices())
    if len(devs) < n_replicas:
        raise ValueError(
            f"replica_mesh needs {n_replicas} devices, "
            f"have {len(devs)}")
    return Mesh(np.array(devs[:n_replicas]), axis_names=("replicas",))


def replica_stack_spec() -> P:
    """PartitionSpec for a pytree stacked along a leading replica axis:
    shard dim 0 over `replicas`, replicate the rest."""
    return P("replicas")


def model_shards(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def data_shards(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


# --- activation specs -------------------------------------------------------

def act_bsd(ax: Axes) -> P:
    """(batch, seq, d_model): batch over data axes, rest replicated."""
    return P(ax.batch, None, None)


def tokens_bs(ax: Axes) -> P:
    return P(ax.batch, None)


def kv_cache_spec(ax: Axes, seq_sharded: bool) -> P:
    """KV cache (layers, batch, seq, kv_heads, head_dim).

    Decode at long context shards the *sequence* dim over 'model' (SP) —
    kv_heads is usually smaller than the model axis, sequence is not."""
    if seq_sharded:
        return P(None, ax.batch, ax.model, None, None)
    return P(None, ax.batch, None, ax.model, None)


# --- parameter specs ---------------------------------------------------------

def embed_spec(ax: Axes) -> P:
    return P(ax.model, None)            # vocab-sharded embedding


def head_proj_spec(ax: Axes) -> P:
    return P(None, ax.model, None)      # (d_model, heads, head_dim): TP by head


def o_proj_spec(ax: Axes) -> P:
    return P(ax.model, None, None)      # (heads, head_dim, d_model)


def ffn_col_spec(ax: Axes) -> P:
    return P(None, ax.model)            # (d_model, d_ff): column parallel


def ffn_row_spec(ax: Axes) -> P:
    return P(ax.model, None)            # (d_ff, d_model): row parallel


def expert_col_spec(ax: Axes) -> P:
    return P(ax.model, None, None)      # (E, d_model, d_ff): EP over experts


def expert_row_spec(ax: Axes) -> P:
    return P(ax.model, None, None)      # (E, d_ff, d_model)


def replicated() -> P:
    return P()


def dsg_fw_spec(ax: Axes) -> P:
    """f(W) buffer (k, F): F follows the FFN column sharding."""
    return P(None, ax.model)


def dsg_fw_expert_spec(ax: Axes) -> P:
    return P(ax.model, None, None)      # (E, k, F): follows experts


def with_layer_dim(spec: P) -> P:
    """Prefix a replicated layer-stack dim (scan over layers)."""
    return P(None, *spec)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def constrain(x, mesh: Mesh, spec: P):
    """Sharding constraint helper usable inside jit."""
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
