"""repro — Dynamic Sparse Graph (DSG, ICLR 2019) as a pod-scale JAX framework."""
__version__ = "1.0.0"
