"""Pluggable KV-cache backends behind a unified `CacheHandle`.

The serving engine used to hard-wire the dense worst-case cache layout
(L, n_slots, Smax, Kv, D) into api.py / attention.py / scheduler.py.  This
module makes the layout a backend choice:

    backend = get_backend("paged", page_size=16, total_tokens=512)
    handle  = backend.make(cfg, n_slots, max_seq)       # opaque CacheHandle
    handle  = backend.write(handle, lane_kv, slot,      # admission splice
                            n_tokens=pb, reserve_tokens=need)
    handle  = backend.ensure(handle, slot, pos)         # growth while decoding
    handle  = backend.free(handle, slot)                # retirement
    data    = backend.view_for_attention(handle)        # pytree for forward()

`CacheHandle` is a registered pytree, so the engine's jitted steps take and
return it directly (buffer donation included); `kind` and `page_size` ride
in the static treedef.

`DenseBackend` keeps today's layout and is the equivalence baseline.
`PagedBackend` stores K/V in fixed-size pages of `page_size` tokens:

    pages_k / pages_v : (L, n_pages, page_size, Kv, D)   physical pool
    page_table        : (n_slots, max_seq // page_size)  int32 logical->physical

A host-side free-list `BlockAllocator` hands out physical pages; lanes
allocate pages as `pos` grows and return them on retirement, so short
requests stop paying worst-case `Smax` memory — the DSG move (exploit
runtime-dynamic sparsity in the data layout instead of a dense worst-case
structure) applied to the serving memory plane.  Physical page 0 is a
reserved scratch page: unallocated page-table entries point at it, so
gathers beyond a lane's depth read defined (masked-out) memory.  Free
lanes never address it during decode — the engine mirrors the donor
lane's page-table row for them, which keeps shared-threshold DRS
deterministic (see decode_view below).

Out-of-pages policy: admission reserves the pages a request could ever
need (`reserve_tokens`, normally `min(prompt_bucket + max_new, max_seq)`)
and `can_admit` gates on free-minus-reserved, so `ensure` growth never
fails mid-decode; a pool smaller than one request's reservation surfaces
as a deferred admission, not silent corruption.

Copy-on-write prefix sharing (PagedBackend(prefix_sharing=True), see
docs/cache_backends.md): the allocator refcounts pages and keeps a
prefix-hash index over prompt token blocks (`prefix_chain`), so an
admission whose padded prompt matches an already-resident prefix maps
the existing pages (refcount bump, no scatter, no fresh allocation)
instead of recomputing them.  The only shared page a decode write can
ever land in is a partial prompt-tail page (growth pages allocated by
`ensure` are never indexed); writing it while its refcount is > 1
triggers copy-on-write into a private page, paid for by one extra
reserved page per admission with a partial tail — so `ensure` stays
infallible.  `free` is release semantics: decrement, return the page to
the free list only at refcount zero, and drop its index entries there —
the index only ever points at live pages.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, transformer

NULL_PAGE = 0          # reserved scratch page; never handed out

BACKENDS = ("dense", "paged")


class OutOfPages(RuntimeError):
    """The block allocator has fewer free pages than requested."""


@jax.tree_util.register_pytree_node_class
@dataclass
class CacheHandle:
    """Opaque KV-cache pytree + static layout tag.

    `data` holds the device arrays (dense: {'k','v'}; paged:
    {'pages_k','pages_v','page_table'}); `kind`/`page_size` are static
    aux data, so jitted functions can rebuild the handle around updated
    leaves without retracing on layout.
    """
    data: dict
    kind: str = "dense"
    page_size: int = 0

    def tree_flatten(self):
        return (self.data,), (self.kind, self.page_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


# ---------------------------------------------------------------------------
# block allocator (host-side)
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Refcounting free-list allocator over physical page ids
    [reserved, n_pages).

    Page ids below `reserved` are never handed out (id 0 is the paged
    backend's scratch page).  O(1) alloc/free; over-allocation raises
    `OutOfPages`, double-free and foreign ids raise `ValueError`.

    Sharing surface (copy-on-write prefix reuse): `alloc` hands pages
    out at refcount 1, `share` bumps an already-live page, and `free`
    has RELEASE semantics — it decrements and only returns a page to
    the free list at refcount zero, so a fault-path reclaim of a lane
    holding shared pages decrements, never frees, pages other lanes
    still read.  `register`/`lookup` maintain the prefix-hash index
    (content key -> live page); entries drop automatically when their
    page's refcount hits zero, so the index never points at a freed
    page.  `peak_live` is the high-water mark of distinct live pages —
    the resident-page number bench_prefix_sharing.py gates on.
    """

    def __init__(self, n_pages: int, reserved: int = 0):
        if n_pages <= reserved:
            raise ValueError("allocator needs at least one allocatable page")
        self.n_pages = n_pages
        self.reserved = reserved
        self._free = list(range(n_pages - 1, reserved - 1, -1))
        self._rc: dict = {}                # live page -> refcount (>= 1)
        self._index: dict = {}             # prefix key -> live page
        self._page_keys: dict = {}         # live page -> [registered keys]
        self.peak_live = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Distinct pages currently allocated (refcounts ignored)."""
        return len(self._rc)

    def reset_peak(self) -> None:
        """Restart the live-page high-water mark at the current
        occupancy (benchmarks call this after warmup)."""
        self.peak_live = len(self._rc)

    def refcount(self, page: int) -> int:
        """Current refcount (0 for pages not live)."""
        return self._rc.get(page, 0)

    def alloc(self, n: int) -> list:
        if n > len(self._free):
            raise OutOfPages(
                f"requested {n} pages, only {len(self._free)} free of "
                f"{self.n_pages - self.reserved}")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._rc[p] = 1
        self.peak_live = max(self.peak_live, len(self._rc))
        return out

    def share(self, page: int) -> int:
        """Add a reference to a live page (a lane mapping an existing
        shared-prefix page); returns the new refcount."""
        if page not in self._rc:
            raise ValueError(f"page {page} is not currently allocated")
        self._rc[page] += 1
        return self._rc[page]

    def free(self, pages) -> None:
        """Release one reference per page: the page returns to the free
        list (and its index entries drop) only when no other holder
        remains."""
        for p in pages:
            if p not in self._rc:
                raise ValueError(f"page {p} is not currently allocated")
            self._rc[p] -= 1
            if self._rc[p]:
                continue
            del self._rc[p]
            for key in self._page_keys.pop(p, ()):
                if self._index.get(key) == p:
                    del self._index[key]
            self._free.append(p)

    # -- prefix-hash index ---------------------------------------------------

    def register(self, key: bytes, page: int) -> None:
        """Publish a live page under a prefix content key so later
        admissions with the same prompt blocks can `share` it.  First
        writer wins: an already-registered key keeps its page (both hold
        identical content; two entries would just split future sharers)."""
        if page not in self._rc:
            raise ValueError(
                f"cannot register freed page {page} in the prefix index")
        if key in self._index:
            return
        self._index[key] = page
        self._page_keys.setdefault(page, []).append(key)

    def lookup(self, key: bytes) -> Optional[int]:
        """The live page registered under `key`, or None.  Entries are
        dropped at free time, so a hit is always safe to `share`."""
        return self._index.get(key)

    @property
    def index_size(self) -> int:
        return len(self._index)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

def decode_view(handle: CacheHandle, free_mask=None, donor=None) -> dict:
    """The per-step attention view of a handle (jit-friendly; the serving
    engine calls this inside its jitted decode step).

    No logical (B, Smax, ...) window is ever materialized: the paged view
    is the physical pools + page table exactly as stored, and the
    per-lane depths ride separately as the decode `pos` vector — the
    attention executor (Pallas kernel or bounded XLA gather) walks only
    the pages at or below each lane's depth.

    free_mask/donor: a free paged lane's table row is all NULL — left
    alone it would gather scratch-page junk (nondeterministic row-0
    scores under shared-threshold DRS, since mirrored lanes also scatter
    to one scratch slot and the duplicate-index winner is unspecified).
    Mirroring the donor's page-table row instead makes free lanes exact
    clones of the donor: they read the donor's K/V and re-write the
    donor's own values to the donor's pages (identical duplicates are
    order-independent), so paged decode is deterministic in every
    threshold mode.
    """
    if handle.kind != "paged" or free_mask is None:
        return handle.data
    pt = handle.data["page_table"]
    pt = jnp.where(free_mask[:, None], pt[donor], pt)
    return {**handle.data, "page_table": pt}


class _Backend:
    """Shared backend plumbing: the handle's `data` is always the exact
    pytree `transformer.forward` consumes, and resident bytes are just the
    bytes the handle keeps alive."""

    def view_for_attention(self, handle: CacheHandle, free_mask=None,
                           donor=None) -> dict:
        return decode_view(handle, free_mask, donor)

    def resident_bytes(self, handle: CacheHandle) -> int:
        return sum(leaf.nbytes for leaf in jax.tree.leaves(handle.data))

    def ensure_range(self, handle: CacheHandle, slot: int, start: int,
                     stop: int) -> CacheHandle:
        """Grow lane `slot` to cover writes at every position in
        [start, stop) — the fused decode chunk's pre-reservation, where
        `ensure` moves ahead of the device loop because the scanned
        micro-steps cannot grow the page table mid-dispatch.  The caller
        clamps `stop` to the lane's emit budget so the mapping stays
        inside its admission-time page reservation."""
        for pos in range(start, stop):
            handle = self.ensure(handle, slot, pos)
        return handle


def dense_merge(cache: dict, lane_cache: dict, slot) -> dict:
    """Scatter a 1-lane dense cache into lane `slot` of the batched cache.

    Writes the FULL sequence extent of the lane (not just the prompt), so
    stale K/V left behind by a retired request can never leak into the new
    occupant's attention window.  `slot` may be a traced scalar (the
    function is jit-friendly; backends jit it once).
    """
    def upd(c, lane):
        start = (0, slot) + (0,) * (c.ndim - 2)
        return jax.lax.dynamic_update_slice(c, lane.astype(c.dtype), start)
    return jax.tree.map(upd, cache, lane_cache)


class DenseBackend(_Backend):
    """Worst-case dense layout: every cache leaf is (L, n_slots, Smax, ...).

    Admission is a lane-to-lane scatter; `free`/`ensure` are no-ops (each
    lane permanently owns its Smax stripe).
    """

    kind = "dense"
    page_size = 0

    def __init__(self):
        self._merge = jax.jit(dense_merge, donate_argnums=(0,))

    def make(self, cfg, n_slots: int, max_seq: int, dtype=None) -> CacheHandle:
        return CacheHandle(api.make_cache(cfg, n_slots, max_seq, dtype),
                           "dense", 0)

    def write(self, handle: CacheHandle, slot_kv: dict, slot,
              n_tokens: Optional[int] = None,
              reserve_tokens: Optional[int] = None,
              chain=None) -> CacheHandle:
        return CacheHandle(self._merge(handle.data, slot_kv, slot), "dense", 0)

    def ensure(self, handle: CacheHandle, slot: int, pos: int) -> CacheHandle:
        return handle

    def free(self, handle: CacheHandle, slot: int) -> CacheHandle:
        return handle

    def can_admit(self, n_tokens: int, chain=None,
                  prompt_tokens: Optional[int] = None) -> bool:
        return True


# ---------------------------------------------------------------------------
# paged backend
# ---------------------------------------------------------------------------

def prefix_chain(tokens: np.ndarray, page_size: int) -> list:
    """Chained content keys for each page of a padded prompt row: key i
    commits to EVERY token in positions [0, min((i+1)*page_size, len)),
    so two prompts share key i iff their padded rows agree on the whole
    prefix through page i — exactly the condition under which page i's
    K/V bytes are identical (page content is a pure function of the
    tokens at and before it).  keyed blake2b, not python hash():
    PYTHONHASHSEED salting would break cross-process determinism.

    The engine hashes the BUCKETED row (left-padding included), so only
    prompts landing in the same bucket with identical padding can share
    — which is also the only case where their page bytes match.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    if toks.ndim != 1:
        raise ValueError(f"prefix_chain wants a 1-D token row, "
                         f"got shape {toks.shape}")
    keys, prev = [], b""
    for start in range(0, len(toks), page_size):
        blk = toks[start:start + page_size].tobytes()
        prev = hashlib.blake2b(prev + blk, digest_size=16).digest()
        keys.append(prev)
    return keys


def _paged_merge(pools: dict, lane: dict, pp: jax.Array) -> dict:
    """Scatter the leading `len(pp)` pages of a 1-lane dense cache into the
    physical pages `pp` of the pool (one compile per page count, i.e. per
    prompt bucket).  Freshly allocated pages are fully overwritten, so a
    previous occupant's K/V cannot leak.
    """
    ps = pools["pages_k"].shape[2]
    n_lp = pp.shape[0]

    def upd(pool, lane_leaf):
        l, _, _, kv, d = lane_leaf.shape
        chunks = lane_leaf[:, 0, :n_lp * ps].reshape(l, n_lp, ps, kv, d)
        return pool.at[:, pp].set(chunks.astype(pool.dtype))

    return {"pages_k": upd(pools["pages_k"], lane["k"]),
            "pages_v": upd(pools["pages_v"], lane["v"])}


def _paged_merge_subset(pools: dict, lane: dict, pp: jax.Array,
                        lps: jax.Array, n_lp: int) -> dict:
    """_paged_merge for a shared-prefix admission: scatter only the
    logical pages `lps` (the NON-shared ones) of the lane's first `n_lp`
    pages into physical pages `pp` — shared pages already hold identical
    bytes and must not be rewritten (other lanes read them).  One
    compile per (n_lp, len(lps)) pair."""
    ps = pools["pages_k"].shape[2]

    def upd(pool, lane_leaf):
        l, _, _, kv, d = lane_leaf.shape
        chunks = lane_leaf[:, 0, :n_lp * ps].reshape(l, n_lp, ps, kv, d)
        return pool.at[:, pp].set(chunks[:, lps].astype(pool.dtype))

    return {"pages_k": upd(pools["pages_k"], lane["k"]),
            "pages_v": upd(pools["pages_v"], lane["v"])}


def _page_copy(pools: dict, src: jax.Array, dst: jax.Array) -> dict:
    """Copy one physical page across every layer (the copy half of
    copy-on-write).  src/dst ride as traced scalars — one compile
    total, not one per page id."""
    return {"pages_k": pools["pages_k"].at[:, dst]
            .set(pools["pages_k"][:, src]),
            "pages_v": pools["pages_v"].at[:, dst]
            .set(pools["pages_v"][:, src])}


class PagedBackend(_Backend):
    """Fixed-size pages + per-lane page table + host free-list allocator.

    The pool holds `total_tokens` worth of pages (default: the dense
    worst case `n_slots * max_seq`; size it to expected peak concurrent
    demand to realise the memory saving).  One backend instance manages
    one live handle: the allocator and the host page-table mirror are the
    source of truth, and every mutation returns a handle with a fresh
    device copy of the (tiny) page table.
    """

    kind = "paged"

    def __init__(self, page_size: int = 16,
                 total_tokens: Optional[int] = None,
                 prefix_sharing: bool = False):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.page_size = page_size
        self.total_tokens = total_tokens
        self.prefix_sharing = bool(prefix_sharing)
        self.cow_copies = 0             # COW events (test/bench counter)
        self.shared_page_hits = 0       # pages mapped without a scatter
        self.allocator: Optional[BlockAllocator] = None
        self._table: Optional[np.ndarray] = None
        self._resv: Optional[np.ndarray] = None
        self._merge = jax.jit(_paged_merge, donate_argnums=(0,))
        self._merge_subset = jax.jit(_paged_merge_subset,
                                     donate_argnums=(0,),
                                     static_argnums=(4,))
        self._copy_page = jax.jit(_page_copy, donate_argnums=(0,))

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def make(self, cfg, n_slots: int, max_seq: int, dtype=None) -> CacheHandle:
        if self._table is not None:
            raise RuntimeError("PagedBackend manages one live handle; "
                               "create a fresh backend per engine")
        if cfg.family not in api.DECODER_FAMILIES:
            raise NotImplementedError(
                f"paged KV cache supports decoder families only, "
                f"not {cfg.family!r}")
        if max_seq % self.page_size:
            raise ValueError(f"max_seq={max_seq} must be a multiple of "
                             f"page_size={self.page_size}")
        total = self.total_tokens or n_slots * max_seq
        n_pages = self.pages_for(total) + 1        # +1: scratch page 0
        dt = dtype or api._dtype(cfg)   # same default as the dense cache
        pool = transformer.init_paged_cache(cfg, n_pages, self.page_size, dt)
        self.allocator = BlockAllocator(n_pages, reserved=1)
        self.max_pages = max_seq // self.page_size
        self._table = np.full((n_slots, self.max_pages), NULL_PAGE, np.int32)
        self._resv = np.zeros(n_slots, np.int64)
        data = {"pages_k": pool["k"], "pages_v": pool["v"],
                "page_table": jnp.asarray(self._table)}
        return CacheHandle(data, "paged", self.page_size)

    def shared_hits(self, chain: Sequence[bytes]) -> int:
        """Leading run of chain keys with a live indexed page — the pages
        an admission with this prompt chain would map instead of
        allocating.  A chain key can only be resident when every earlier
        one is (all holders map a contiguous leading prefix), so the scan
        stops at the first miss."""
        if not self.prefix_sharing or chain is None:
            return 0
        hits = 0
        for key in chain:
            if self.allocator.lookup(key) is None:
                break
            hits += 1
        return hits

    def sharing_adjustment(self, chain,
                           prompt_tokens: Optional[int]) -> int:
        """Worst-case page-count adjustment for a sharing admission:
        MINUS the full prompt pages already resident (mapped, not
        allocated), PLUS one COW page when the prompt tail only part-
        fills its page — the one shared page a decode write can land in.
        The +1 is charged whether or not the tail is shared YET: the
        registrant's tail can be shared by a LATER admission, and the
        registrant then needs the COW page for its own next write."""
        if not self.prefix_sharing or prompt_tokens is None:
            return 0
        tail = 1 if prompt_tokens % self.page_size else 0
        full = prompt_tokens // self.page_size
        saved = min(self.shared_hits(chain), full) if chain else 0
        return tail - saved

    def can_admit(self, n_tokens: int, chain=None,
                  prompt_tokens: Optional[int] = None) -> bool:
        """True when free-minus-reserved pages cover a request reserving
        `n_tokens`; gating admissions on this makes `ensure` growth (and
        copy-on-write) infallible for already-admitted lanes.  With
        prefix sharing, `chain`/`prompt_tokens` credit the full prompt
        pages already resident and charge the partial-tail COW page —
        the same arithmetic `write` commits to."""
        need = self.pages_for(n_tokens) \
            + self.sharing_adjustment(chain, prompt_tokens)
        return (self.allocator.free_pages - int(self._resv.sum()) >= need)

    def write(self, handle: CacheHandle, slot_kv: Optional[dict], slot: int,
              n_tokens: Optional[int] = None,
              reserve_tokens: Optional[int] = None,
              chain: Optional[Sequence[bytes]] = None) -> CacheHandle:
        """Splice a prefilled 1-lane dense cache into lane `slot`: allocate
        pages covering the first `n_tokens` positions and scatter the
        lane's K/V into them; `reserve_tokens` (>= n_tokens) additionally
        reserves growth pages so later `ensure` calls cannot run out.

        With prefix sharing, `chain` (one prefix_chain key per prompt
        page) maps the leading already-resident run by refcount bump —
        no allocation, no scatter — and registers the freshly written
        pages for later admissions.  When EVERY prompt page is shared the
        caller may pass slot_kv=None (the zero-recompute path: no
        prefill output is needed at all)."""
        if n_tokens is None:
            raise ValueError("paged write needs n_tokens (the prompt extent)")
        self._release(slot)
        n_lp = self.pages_for(n_tokens)
        need = max(self.pages_for(reserve_tokens), n_lp) \
            if reserve_tokens else n_lp
        sharing = self.prefix_sharing and chain is not None
        hits = 0
        if sharing:
            if len(chain) != n_lp:
                raise ValueError(
                    f"chain must carry one key per prompt page "
                    f"({n_lp}), got {len(chain)}")
            hits = self.shared_hits(chain)
        fresh_lps = list(range(hits, n_lp))
        # alloc before share: an OutOfPages raise (admission mis-gated)
        # leaves no dangling refcounts
        pp = self.allocator.alloc(len(fresh_lps))
        for i in range(hits):
            pg = self.allocator.lookup(chain[i])
            self.allocator.share(pg)
            self._table[slot, i] = pg
        self.shared_page_hits += hits
        for lp, pg in zip(fresh_lps, pp):
            self._table[slot, lp] = pg
            if sharing:
                self.allocator.register(chain[lp], pg)
        # reservation: growth pages beyond the prompt extent, plus the
        # partial-tail COW page (see _extra_pages; consumed by _cow)
        tail = 1 if sharing and n_tokens % self.page_size else 0
        self._resv[slot] = need - n_lp + tail
        pools = {"pages_k": handle.data["pages_k"],
                 "pages_v": handle.data["pages_v"]}
        if fresh_lps:
            if slot_kv is None:
                raise ValueError(
                    f"write(slot_kv=None) needs every prompt page shared "
                    f"({hits} of {n_lp} resident)")
            if hits:
                pools = self._merge_subset(
                    pools, slot_kv, jnp.asarray(pp, jnp.int32),
                    jnp.asarray(fresh_lps, jnp.int32), n_lp)
            else:
                pools = self._merge(pools, slot_kv,
                                    jnp.asarray(pp, jnp.int32))
        pools["page_table"] = jnp.asarray(self._table)
        return CacheHandle(pools, "paged", self.page_size)

    def _cow(self, handle: CacheHandle, slot: int, lp: int) -> CacheHandle:
        """Copy-on-write lane `slot`'s logical page `lp` into a private
        physical page: the lane is about to write a page other lanes
        still read.  Copies the page bytes exactly (positions beyond any
        reader's depth are masked junk either way), releases this lane's
        reference on the shared page — never freeing it, other holders
        remain — and spends the lane's reserved COW page."""
        old = int(self._table[slot, lp])
        (new,) = self.allocator.alloc(1)
        self.allocator.free([old])      # rc > 1: decrements, stays live
        self._table[slot, lp] = new
        self._resv[slot] = max(int(self._resv[slot]) - 1, 0)
        self.cow_copies += 1
        pools = self._copy_page(
            {"pages_k": handle.data["pages_k"],
             "pages_v": handle.data["pages_v"]},
            jnp.int32(old), jnp.int32(new))
        pools["page_table"] = jnp.asarray(self._table)
        return CacheHandle(pools, "paged", self.page_size)

    def ensure(self, handle: CacheHandle, slot: int, pos: int) -> CacheHandle:
        """Grow lane `slot` to cover a write at position `pos` (no-op when
        the covering page is already mapped and privately held; a mapped
        page still shared with other lanes is copied-on-write first)."""
        lp = pos // self.page_size
        pg = int(self._table[slot, lp])
        if pg != NULL_PAGE:
            if self.prefix_sharing and self.allocator.refcount(pg) > 1:
                return self._cow(handle, slot, lp)
            return handle
        (pg,) = self.allocator.alloc(1)
        self._table[slot, lp] = pg
        self._resv[slot] = max(int(self._resv[slot]) - 1, 0)
        return CacheHandle({**handle.data,
                            "page_table": jnp.asarray(self._table)},
                           "paged", self.page_size)

    def ensure_range(self, handle: CacheHandle, slot: int, start: int,
                     stop: int) -> CacheHandle:
        """Map every page covering writes in [start, stop), pushing the
        device page table once instead of once per newly-mapped page.
        Shared mapped pages in the range are copied-on-write (the fused
        chunk will write them mid-scan, when the host cannot intervene)."""
        grew = False
        for lp in range(start // self.page_size,
                        (stop - 1) // self.page_size + 1):
            pg = int(self._table[slot, lp])
            if pg != NULL_PAGE:
                if self.prefix_sharing and self.allocator.refcount(pg) > 1:
                    handle = self._cow(handle, slot, lp)
                continue
            (pg,) = self.allocator.alloc(1)
            self._table[slot, lp] = pg
            self._resv[slot] = max(int(self._resv[slot]) - 1, 0)
            grew = True
        if not grew:
            return handle
        return CacheHandle({**handle.data,
                            "page_table": jnp.asarray(self._table)},
                           "paged", self.page_size)

    def free(self, handle: CacheHandle, slot: int) -> CacheHandle:
        """Return lane `slot`'s pages to the free list (retirement)."""
        self._release(slot)
        return CacheHandle({**handle.data,
                            "page_table": jnp.asarray(self._table)},
                           "paged", self.page_size)

    def _release(self, slot: int) -> None:
        pages = [int(p) for p in self._table[slot] if p != NULL_PAGE]
        if pages:
            self.allocator.free(pages)
        self._table[slot] = NULL_PAGE
        self._resv[slot] = 0


def get_backend(name: str, *, page_size: int = 16,
                total_tokens: Optional[int] = None,
                prefix_sharing: bool = False):
    """Factory: "dense" -> DenseBackend, "paged" -> PagedBackend."""
    if name == "dense":
        if prefix_sharing:
            raise ValueError("prefix_sharing needs the paged backend: "
                             "the dense layout has no pages to share")
        return DenseBackend()
    if name == "paged":
        return PagedBackend(page_size=page_size, total_tokens=total_tokens,
                            prefix_sharing=prefix_sharing)
    raise ValueError(f"unknown cache backend {name!r}; "
                     f"expected one of {BACKENDS}")
