"""Pluggable KV-cache backends behind a unified `CacheHandle`.

The serving engine used to hard-wire the dense worst-case cache layout
(L, n_slots, Smax, Kv, D) into api.py / attention.py / scheduler.py.  This
module makes the layout a backend choice:

    backend = get_backend("paged", page_size=16, total_tokens=512)
    handle  = backend.make(cfg, n_slots, max_seq)       # opaque CacheHandle
    handle  = backend.write(handle, lane_kv, slot,      # admission splice
                            n_tokens=pb, reserve_tokens=need)
    handle  = backend.ensure(handle, slot, pos)         # growth while decoding
    handle  = backend.free(handle, slot)                # retirement
    data    = backend.view_for_attention(handle)        # pytree for forward()

`CacheHandle` is a registered pytree, so the engine's jitted steps take and
return it directly (buffer donation included); `kind` and `page_size` ride
in the static treedef.

`DenseBackend` keeps today's layout and is the equivalence baseline.
`PagedBackend` stores K/V in fixed-size pages of `page_size` tokens:

    pages_k / pages_v : (L, n_pages, page_size, Kv, D)   physical pool
    page_table        : (n_slots, max_seq // page_size)  int32 logical->physical

A host-side free-list `BlockAllocator` hands out physical pages; lanes
allocate pages as `pos` grows and return them on retirement, so short
requests stop paying worst-case `Smax` memory — the DSG move (exploit
runtime-dynamic sparsity in the data layout instead of a dense worst-case
structure) applied to the serving memory plane.  Physical page 0 is a
reserved scratch page: unallocated page-table entries point at it, so
gathers beyond a lane's depth read defined (masked-out) memory.  Free
lanes never address it during decode — the engine mirrors the donor
lane's page-table row for them, which keeps shared-threshold DRS
deterministic (see decode_view below).

Out-of-pages policy: admission reserves the pages a request could ever
need (`reserve_tokens`, normally `min(prompt_bucket + max_new, max_seq)`)
and `can_admit` gates on free-minus-reserved, so `ensure` growth never
fails mid-decode; a pool smaller than one request's reservation surfaces
as a deferred admission, not silent corruption.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, transformer

NULL_PAGE = 0          # reserved scratch page; never handed out

BACKENDS = ("dense", "paged")


class OutOfPages(RuntimeError):
    """The block allocator has fewer free pages than requested."""


@jax.tree_util.register_pytree_node_class
@dataclass
class CacheHandle:
    """Opaque KV-cache pytree + static layout tag.

    `data` holds the device arrays (dense: {'k','v'}; paged:
    {'pages_k','pages_v','page_table'}); `kind`/`page_size` are static
    aux data, so jitted functions can rebuild the handle around updated
    leaves without retracing on layout.
    """
    data: dict
    kind: str = "dense"
    page_size: int = 0

    def tree_flatten(self):
        return (self.data,), (self.kind, self.page_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


# ---------------------------------------------------------------------------
# block allocator (host-side)
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Free-list allocator over physical page ids [reserved, n_pages).

    Page ids below `reserved` are never handed out (id 0 is the paged
    backend's scratch page).  O(1) alloc/free; over-allocation raises
    `OutOfPages`, double-free and foreign ids raise `ValueError`.
    """

    def __init__(self, n_pages: int, reserved: int = 0):
        if n_pages <= reserved:
            raise ValueError("allocator needs at least one allocatable page")
        self.n_pages = n_pages
        self.reserved = reserved
        self._free = list(range(n_pages - 1, reserved - 1, -1))
        self._live: set = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list:
        if n > len(self._free):
            raise OutOfPages(
                f"requested {n} pages, only {len(self._free)} free of "
                f"{self.n_pages - self.reserved}")
        out = [self._free.pop() for _ in range(n)]
        self._live.update(out)
        return out

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._live:
                raise ValueError(f"page {p} is not currently allocated")
            self._live.remove(p)
            self._free.append(p)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

def decode_view(handle: CacheHandle, free_mask=None, donor=None) -> dict:
    """The per-step attention view of a handle (jit-friendly; the serving
    engine calls this inside its jitted decode step).

    No logical (B, Smax, ...) window is ever materialized: the paged view
    is the physical pools + page table exactly as stored, and the
    per-lane depths ride separately as the decode `pos` vector — the
    attention executor (Pallas kernel or bounded XLA gather) walks only
    the pages at or below each lane's depth.

    free_mask/donor: a free paged lane's table row is all NULL — left
    alone it would gather scratch-page junk (nondeterministic row-0
    scores under shared-threshold DRS, since mirrored lanes also scatter
    to one scratch slot and the duplicate-index winner is unspecified).
    Mirroring the donor's page-table row instead makes free lanes exact
    clones of the donor: they read the donor's K/V and re-write the
    donor's own values to the donor's pages (identical duplicates are
    order-independent), so paged decode is deterministic in every
    threshold mode.
    """
    if handle.kind != "paged" or free_mask is None:
        return handle.data
    pt = handle.data["page_table"]
    pt = jnp.where(free_mask[:, None], pt[donor], pt)
    return {**handle.data, "page_table": pt}


class _Backend:
    """Shared backend plumbing: the handle's `data` is always the exact
    pytree `transformer.forward` consumes, and resident bytes are just the
    bytes the handle keeps alive."""

    def view_for_attention(self, handle: CacheHandle, free_mask=None,
                           donor=None) -> dict:
        return decode_view(handle, free_mask, donor)

    def resident_bytes(self, handle: CacheHandle) -> int:
        return sum(leaf.nbytes for leaf in jax.tree.leaves(handle.data))

    def ensure_range(self, handle: CacheHandle, slot: int, start: int,
                     stop: int) -> CacheHandle:
        """Grow lane `slot` to cover writes at every position in
        [start, stop) — the fused decode chunk's pre-reservation, where
        `ensure` moves ahead of the device loop because the scanned
        micro-steps cannot grow the page table mid-dispatch.  The caller
        clamps `stop` to the lane's emit budget so the mapping stays
        inside its admission-time page reservation."""
        for pos in range(start, stop):
            handle = self.ensure(handle, slot, pos)
        return handle


def dense_merge(cache: dict, lane_cache: dict, slot) -> dict:
    """Scatter a 1-lane dense cache into lane `slot` of the batched cache.

    Writes the FULL sequence extent of the lane (not just the prompt), so
    stale K/V left behind by a retired request can never leak into the new
    occupant's attention window.  `slot` may be a traced scalar (the
    function is jit-friendly; backends jit it once).
    """
    def upd(c, lane):
        start = (0, slot) + (0,) * (c.ndim - 2)
        return jax.lax.dynamic_update_slice(c, lane.astype(c.dtype), start)
    return jax.tree.map(upd, cache, lane_cache)


class DenseBackend(_Backend):
    """Worst-case dense layout: every cache leaf is (L, n_slots, Smax, ...).

    Admission is a lane-to-lane scatter; `free`/`ensure` are no-ops (each
    lane permanently owns its Smax stripe).
    """

    kind = "dense"
    page_size = 0

    def __init__(self):
        self._merge = jax.jit(dense_merge, donate_argnums=(0,))

    def make(self, cfg, n_slots: int, max_seq: int, dtype=None) -> CacheHandle:
        return CacheHandle(api.make_cache(cfg, n_slots, max_seq, dtype),
                           "dense", 0)

    def write(self, handle: CacheHandle, slot_kv: dict, slot,
              n_tokens: Optional[int] = None,
              reserve_tokens: Optional[int] = None) -> CacheHandle:
        return CacheHandle(self._merge(handle.data, slot_kv, slot), "dense", 0)

    def ensure(self, handle: CacheHandle, slot: int, pos: int) -> CacheHandle:
        return handle

    def free(self, handle: CacheHandle, slot: int) -> CacheHandle:
        return handle

    def can_admit(self, n_tokens: int) -> bool:
        return True


# ---------------------------------------------------------------------------
# paged backend
# ---------------------------------------------------------------------------

def _paged_merge(pools: dict, lane: dict, pp: jax.Array) -> dict:
    """Scatter the leading `len(pp)` pages of a 1-lane dense cache into the
    physical pages `pp` of the pool (one compile per page count, i.e. per
    prompt bucket).  Freshly allocated pages are fully overwritten, so a
    previous occupant's K/V cannot leak.
    """
    ps = pools["pages_k"].shape[2]
    n_lp = pp.shape[0]

    def upd(pool, lane_leaf):
        l, _, _, kv, d = lane_leaf.shape
        chunks = lane_leaf[:, 0, :n_lp * ps].reshape(l, n_lp, ps, kv, d)
        return pool.at[:, pp].set(chunks.astype(pool.dtype))

    return {"pages_k": upd(pools["pages_k"], lane["k"]),
            "pages_v": upd(pools["pages_v"], lane["v"])}


class PagedBackend(_Backend):
    """Fixed-size pages + per-lane page table + host free-list allocator.

    The pool holds `total_tokens` worth of pages (default: the dense
    worst case `n_slots * max_seq`; size it to expected peak concurrent
    demand to realise the memory saving).  One backend instance manages
    one live handle: the allocator and the host page-table mirror are the
    source of truth, and every mutation returns a handle with a fresh
    device copy of the (tiny) page table.
    """

    kind = "paged"

    def __init__(self, page_size: int = 16,
                 total_tokens: Optional[int] = None):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.page_size = page_size
        self.total_tokens = total_tokens
        self.allocator: Optional[BlockAllocator] = None
        self._table: Optional[np.ndarray] = None
        self._resv: Optional[np.ndarray] = None
        self._merge = jax.jit(_paged_merge, donate_argnums=(0,))

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def make(self, cfg, n_slots: int, max_seq: int, dtype=None) -> CacheHandle:
        if self._table is not None:
            raise RuntimeError("PagedBackend manages one live handle; "
                               "create a fresh backend per engine")
        if cfg.family not in api.DECODER_FAMILIES:
            raise NotImplementedError(
                f"paged KV cache supports decoder families only, "
                f"not {cfg.family!r}")
        if max_seq % self.page_size:
            raise ValueError(f"max_seq={max_seq} must be a multiple of "
                             f"page_size={self.page_size}")
        total = self.total_tokens or n_slots * max_seq
        n_pages = self.pages_for(total) + 1        # +1: scratch page 0
        dt = dtype or api._dtype(cfg)   # same default as the dense cache
        pool = transformer.init_paged_cache(cfg, n_pages, self.page_size, dt)
        self.allocator = BlockAllocator(n_pages, reserved=1)
        self.max_pages = max_seq // self.page_size
        self._table = np.full((n_slots, self.max_pages), NULL_PAGE, np.int32)
        self._resv = np.zeros(n_slots, np.int64)
        data = {"pages_k": pool["k"], "pages_v": pool["v"],
                "page_table": jnp.asarray(self._table)}
        return CacheHandle(data, "paged", self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        """True when free-minus-reserved pages cover a request reserving
        `n_tokens`; gating admissions on this makes `ensure` growth
        infallible for already-admitted lanes."""
        return (self.allocator.free_pages - int(self._resv.sum())
                >= self.pages_for(n_tokens))

    def write(self, handle: CacheHandle, slot_kv: dict, slot: int,
              n_tokens: Optional[int] = None,
              reserve_tokens: Optional[int] = None) -> CacheHandle:
        """Splice a prefilled 1-lane dense cache into lane `slot`: allocate
        pages covering the first `n_tokens` positions and scatter the
        lane's K/V into them; `reserve_tokens` (>= n_tokens) additionally
        reserves growth pages so later `ensure` calls cannot run out."""
        if n_tokens is None:
            raise ValueError("paged write needs n_tokens (the prompt extent)")
        self._release(slot)
        n_lp = self.pages_for(n_tokens)
        need = max(self.pages_for(reserve_tokens), n_lp) \
            if reserve_tokens else n_lp
        pp = self.allocator.alloc(n_lp)
        self._table[slot, :n_lp] = pp
        self._resv[slot] = need - n_lp
        pools = {"pages_k": handle.data["pages_k"],
                 "pages_v": handle.data["pages_v"]}
        pools = self._merge(pools, slot_kv, jnp.asarray(pp, jnp.int32))
        pools["page_table"] = jnp.asarray(self._table)
        return CacheHandle(pools, "paged", self.page_size)

    def ensure(self, handle: CacheHandle, slot: int, pos: int) -> CacheHandle:
        """Grow lane `slot` to cover a write at position `pos` (no-op when
        the covering page is already mapped)."""
        lp = pos // self.page_size
        if self._table[slot, lp] != NULL_PAGE:
            return handle
        (pg,) = self.allocator.alloc(1)
        self._table[slot, lp] = pg
        self._resv[slot] = max(int(self._resv[slot]) - 1, 0)
        return CacheHandle({**handle.data,
                            "page_table": jnp.asarray(self._table)},
                           "paged", self.page_size)

    def ensure_range(self, handle: CacheHandle, slot: int, start: int,
                     stop: int) -> CacheHandle:
        """Map every page covering writes in [start, stop), pushing the
        device page table once instead of once per newly-mapped page."""
        grew = False
        for lp in range(start // self.page_size,
                        (stop - 1) // self.page_size + 1):
            if self._table[slot, lp] == NULL_PAGE:
                (pg,) = self.allocator.alloc(1)
                self._table[slot, lp] = pg
                self._resv[slot] = max(int(self._resv[slot]) - 1, 0)
                grew = True
        if not grew:
            return handle
        return CacheHandle({**handle.data,
                            "page_table": jnp.asarray(self._table)},
                           "paged", self.page_size)

    def free(self, handle: CacheHandle, slot: int) -> CacheHandle:
        """Return lane `slot`'s pages to the free list (retirement)."""
        self._release(slot)
        return CacheHandle({**handle.data,
                            "page_table": jnp.asarray(self._table)},
                           "paged", self.page_size)

    def _release(self, slot: int) -> None:
        pages = [int(p) for p in self._table[slot] if p != NULL_PAGE]
        if pages:
            self.allocator.free(pages)
        self._table[slot] = NULL_PAGE
        self._resv[slot] = 0


def get_backend(name: str, *, page_size: int = 16,
                total_tokens: Optional[int] = None):
    """Factory: "dense" -> DenseBackend, "paged" -> PagedBackend."""
    if name == "dense":
        return DenseBackend()
    if name == "paged":
        return PagedBackend(page_size=page_size, total_tokens=total_tokens)
    raise ValueError(f"unknown cache backend {name!r}; "
                     f"expected one of {BACKENDS}")
