"""Synthetic serving workloads + engine measurement harness.

Mixed-length traffic is where overlap admission earns its keep: short and
long prompts (and short and long generations) interleave, so a wave-admission
engine strands free lanes until the whole batch drains while overlap refills
them immediately.  bench_serving.py and `launch/serve.py --workload mixed`
both drive the engine through this module so the numbers agree.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.serving.scheduler import Request, ServingEngine


def mixed_requests(vocab: int, n_requests: int, *, seed: int = 0,
                   prompt_range=(8, 192), max_new_range=(8, 64),
                   eos_id=None, temperature: float = 0.0,
                   top_p: float = 1.0) -> List[Request]:
    """Mixed-length synthetic traffic: uniform prompt lengths and
    generation budgets over the given ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        plen = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
        max_new = int(rng.integers(max_new_range[0], max_new_range[1] + 1))
        prompt = rng.integers(0, vocab, plen, dtype=np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=max_new,
                            eos_id=eos_id, temperature=temperature,
                            top_p=top_p))
    return reqs


def warmup_engine(eng: ServingEngine, vocab: int,
                  warm_temp: float = 0.0, max_steps: int = 100_000):
    """Compile every shape a measured window can hit, then reset the
    engine's counters: one throwaway admission per prompt bucket (the
    prefill variants + the decode step), the sampling decode/admission
    variants when the traffic samples (same compiled shapes for any
    temperature > 0), and every static live-page bucket of the decode
    step (paged engines recompile per pow2 depth bucket — see
    ServingEngine._live_pages; traffic alone only reaches the buckets
    its depths happen to cross)."""
    rng = np.random.default_rng(12345)
    for i, b in enumerate(eng.buckets):
        eng.submit(Request(uid=-1 - i,
                           prompt=rng.integers(0, vocab, b, dtype=np.int32),
                           max_new=2, temperature=warm_temp))
    if warm_temp > 0:    # mixed traffic also hits the greedy-only step
        eng.submit(Request(uid=-1 - len(eng.buckets),
                           prompt=rng.integers(0, vocab, eng.buckets[0],
                                               dtype=np.int32),
                           max_new=2))
    eng.run(max_steps=max_steps)
    eng.warm_decode(sample=warm_temp > 0)
    eng.done.clear()
    eng.steps = 0
    eng.decode_seconds = 0.0
    eng.decode_tokens = 0


def run_workload(cfg, params, dsg, requests: List[Request], *,
                 admission: str = "overlap", n_slots: int = 4,
                 max_seq: int = 384, prompt_bucket: int = 256,
                 cache_backend: str = "dense", page_size: int = 16,
                 cache_tokens=None, seed: int = 0,
                 max_steps: int = 100_000) -> Dict[str, float]:
    """Run one engine over the request list; returns throughput/latency
    stats.  warmup_engine triggers every jit compile first so the
    measurement is steady-state."""
    eng = ServingEngine(cfg, params, dsg, n_slots=n_slots, max_seq=max_seq,
                        prompt_bucket=prompt_bucket, admission=admission,
                        cache_backend=cache_backend, page_size=page_size,
                        cache_tokens=cache_tokens, seed=seed)
    warm_temp = max((r.temperature for r in requests), default=0.0)
    warmup_engine(eng, cfg.vocab, warm_temp, max_steps=max_steps)

    for r in requests:
        eng.submit(r)
    t0 = time.time()
    done = eng.run(max_steps=max_steps)
    wall = time.time() - t0
    toks = sum(len(r.output) for r in done.values())
    lat = eng.latencies()
    return {
        "admission": admission,
        "cache_backend": eng.backend.kind,
        "cache_bytes": int(eng.backend.resident_bytes(eng.cache)),
        "requests": len(done),
        "tokens": toks,
        "truncated": sum(r.truncated for r in done.values()),
        "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "decode_tok_per_s": eng.decode_tok_per_s(),
        "steps": eng.steps,
        "p50_s": float(np.percentile(lat, 50)) if len(lat) else 0.0,
        "p95_s": float(np.percentile(lat, 95)) if len(lat) else 0.0,
    }
