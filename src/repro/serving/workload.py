"""Synthetic serving workloads + engine measurement harness.

Mixed-length traffic is where overlap admission earns its keep: short and
long prompts (and short and long generations) interleave, so a wave-admission
engine strands free lanes until the whole batch drains while overlap refills
them immediately.  bench_serving.py and `launch/serve.py --workload mixed`
both drive the engine through this module so the numbers agree.

Open-loop evaluation (docs/serving.md): closed-loop drains measure the
system at its own pace — every retirement immediately frees capacity for
the next request, so queueing delay never appears.  Production traffic
arrives on ITS schedule; `poisson_arrivals`/`trace_arrivals` +
`run_open_loop` submit requests at wall-clock offsets regardless of
engine state, and `latency_stats` splits the user-visible latency into
TTFT (submit -> first token) and TPOT (steady-state inter-token) —
the two numbers serving SLOs are written against.  SCENARIOS holds the
mixed-tenant presets (chat / batch / long_context);
`shared_prefix_requests` builds the overlapping-prefix traffic the
copy-on-write paged backend dedupes (bench_prefix_sharing.py).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.runtime.fault_tolerance import ServingFaultInjector
from repro.serving.router import Router
from repro.serving.scheduler import Request, ServingEngine


def mixed_requests(vocab: int, n_requests: int, *, seed: int = 0,
                   prompt_range=(8, 192), max_new_range=(8, 64),
                   eos_id=None, temperature: float = 0.0,
                   top_p: float = 1.0) -> List[Request]:
    """Mixed-length synthetic traffic: uniform prompt lengths and
    generation budgets over the given ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        plen = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
        max_new = int(rng.integers(max_new_range[0], max_new_range[1] + 1))
        prompt = rng.integers(0, vocab, plen, dtype=np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=max_new,
                            eos_id=eos_id, temperature=temperature,
                            top_p=top_p))
    return reqs


def skewed_requests(vocab: int, n_requests: int, *, period: int = 2,
                    seed: int = 0,
                    heavy_prompt=(96, 160), heavy_new=(40, 56),
                    light_prompt=(8, 24), light_new=(2, 4),
                    eos_id=None) -> List[Request]:
    """Skewed mixed traffic: every `period`-th request is HEAVY (long
    prompt, long generation), the rest are light.  With `period` equal to
    the replica count, static round-robin routing funnels every heavy
    request onto one replica — the hash-collision pathology bursty
    production traffic hits — while queue-depth-aware routing spreads
    them by live load (benchmarks/bench_router.py measures the gap)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        pr, nr = ((heavy_prompt, heavy_new) if uid % period == 0
                  else (light_prompt, light_new))
        plen = int(rng.integers(pr[0], pr[1] + 1))
        reqs.append(Request(uid=uid,
                            prompt=rng.integers(0, vocab, plen,
                                                dtype=np.int32),
                            max_new=int(rng.integers(nr[0], nr[1] + 1)),
                            eos_id=eos_id))
    return reqs


#: Mixed-tenant scenario presets (docs/serving.md): the three canonical
#: production traffic shapes.  Ranges are in tokens, sized for the smoke
#: model's default engine limits (max_seq 384, prompt_bucket 256).
SCENARIOS = {
    "chat": dict(prompt_range=(8, 48), max_new_range=(16, 48)),
    "batch": dict(prompt_range=(48, 128), max_new_range=(32, 64)),
    "long_context": dict(prompt_range=(128, 256), max_new_range=(8, 24)),
}


def scenario_requests(scenario: str, vocab: int, n_requests: int, *,
                      seed: int = 0, eos_id=None, temperature: float = 0.0,
                      top_p: float = 1.0) -> List[Request]:
    """Single-tenant traffic drawn from a SCENARIOS preset."""
    try:
        preset = SCENARIOS[scenario]
    except KeyError:
        raise ValueError(f"unknown scenario {scenario!r}; expected one of "
                         f"{sorted(SCENARIOS)}") from None
    return mixed_requests(vocab, n_requests, seed=seed, eos_id=eos_id,
                          temperature=temperature, top_p=top_p, **preset)


def mixed_tenant_requests(vocab: int, n_requests: int, *,
                          scenarios=("chat", "batch", "long_context"),
                          seed: int = 0, eos_id=None,
                          temperature: float = 0.0,
                          top_p: float = 1.0) -> List[Request]:
    """Interleaved multi-tenant traffic: request uid i draws its shape
    from scenarios[i % len(scenarios)], so every scheduling window sees
    all tenants at once — the heterogeneity that makes open-loop TTFT
    tails interesting (a long-context prefill ahead of a chat turn)."""
    presets = []
    for s in scenarios:
        if s not in SCENARIOS:
            raise ValueError(f"unknown scenario {s!r}; expected one of "
                             f"{sorted(SCENARIOS)}")
        presets.append(SCENARIOS[s])
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        p = presets[uid % len(presets)]
        plen = int(rng.integers(p["prompt_range"][0],
                                p["prompt_range"][1] + 1))
        max_new = int(rng.integers(p["max_new_range"][0],
                                   p["max_new_range"][1] + 1))
        reqs.append(Request(uid=uid,
                            prompt=rng.integers(0, vocab, plen,
                                                dtype=np.int32),
                            max_new=max_new, eos_id=eos_id,
                            temperature=temperature, top_p=top_p))
    return reqs


def shared_prefix_requests(vocab: int, n_requests: int, *,
                           prompt_len: int = 24, prefix_len: int = 16,
                           max_new: int = 8, seed: int = 0, eos_id=None,
                           temperature: float = 0.0,
                           top_p: float = 1.0) -> List[Request]:
    """Overlapping-prefix traffic: every prompt is `prompt_len` tokens,
    the first `prefix_len` identical (a shared system prompt), the tail
    unique per request.  prompt_len is FIXED on purpose: the engine
    right-aligns prompts into their bucket, so only identically padded
    rows produce identical page bytes — equal-length prompts are the
    shape on which prefix sharing (kv_cache.PagedBackend) can dedupe."""
    if not 0 <= prefix_len <= prompt_len:
        raise ValueError(f"need 0 <= prefix_len ({prefix_len}) <= "
                         f"prompt_len ({prompt_len})")
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len, dtype=np.int32)
    reqs = []
    for uid in range(n_requests):
        suffix = rng.integers(0, vocab, prompt_len - prefix_len,
                              dtype=np.int32)
        reqs.append(Request(uid=uid,
                            prompt=np.concatenate([prefix, suffix]),
                            max_new=max_new, eos_id=eos_id,
                            temperature=temperature, top_p=top_p))
    return reqs


# ---------------------------------------------------------------------------
# open-loop arrivals
# ---------------------------------------------------------------------------

def poisson_arrivals(n: int, rate_rps: float, *, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """Cumulative arrival offsets (seconds) of a Poisson process at
    `rate_rps` requests/second — the standard open-loop arrival model
    (memoryless gaps, bursts included)."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    rng = np.random.default_rng(seed)
    return start + np.cumsum(rng.exponential(1.0 / rate_rps, n))


def trace_arrivals(inter_arrival_s, *, start: float = 0.0) -> np.ndarray:
    """Cumulative arrival offsets from recorded inter-arrival gaps — the
    replay-a-production-trace arrival model."""
    gaps = np.asarray(inter_arrival_s, dtype=float)
    if gaps.ndim != 1:
        raise ValueError("inter_arrival_s must be a 1-D gap sequence")
    if (gaps < 0).any():
        raise ValueError("inter-arrival gaps must be non-negative")
    return start + np.cumsum(gaps)


def run_open_loop(runner, requests: List[Request], arrivals,
                  *, max_steps: int = 200_000) -> Dict[int, Request]:
    """Drive `runner` (a ServingEngine, or a Router on a lockstep
    executor) open-loop: request i is submitted at wall-clock offset
    arrivals[i] whether or not the system has capacity — queueing delay
    lands in TTFT, exactly as a user would see it.  Between arrivals the
    loop steps the runner if it has work, else sleeps until the next
    arrival.  Returns the merged {uid: Request} results.

    Free-running executors own their drive loop and cannot interleave
    timed submissions with ticks, so they are rejected — open-loop
    measurement needs the tick under this loop's control."""
    arrivals = np.asarray(arrivals, dtype=float)
    if len(arrivals) != len(requests):
        raise ValueError(f"{len(requests)} requests but {len(arrivals)} "
                         f"arrival offsets")
    if len(arrivals) > 1 and (np.diff(arrivals) < 0).any():
        raise ValueError("arrival offsets must be non-decreasing")
    is_router = isinstance(runner, Router)
    if is_router and not runner.executor.lockstep:
        raise ValueError(
            f"open-loop driving needs a lockstep runner; executor "
            f"{runner.executor.name!r} free-runs its replicas")
    if is_router:
        busy = runner._busy
    else:
        busy = lambda: bool(runner.queue) or any(      # noqa: E731
            not s.free for s in runner.slots)
    t0 = time.perf_counter()
    i, steps = 0, 0
    while i < len(requests) or busy():
        now = time.perf_counter() - t0
        while i < len(requests) and arrivals[i] <= now:
            runner.submit(requests[i])
            i += 1
        if busy():
            runner.step()
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(
                    f"open-loop run exceeded max_steps={max_steps} with "
                    f"{i}/{len(requests)} submitted")
        elif i < len(requests):
            # idle: sleep toward the next arrival (capped so a long gap
            # still polls, keeping the loop responsive to clock skew)
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.01))
    return runner.done() if is_router else dict(runner.done)


def warm_temp_for(requests, warm_temp: float = 0.0) -> float:
    """The warmup temperature a request list needs: any request with
    temperature > 0 means the sampling decode/admission variants must be
    pre-compiled, or the first sampled request to arrive lands a jit
    compile inside the measured window.  Callers that know their traffic
    should pass it to warmup_engine/warmup_router via `requests=` (which
    routes through here) instead of hand-picking warm_temp."""
    return max((r.temperature for r in requests), default=warm_temp)


def warmup_engine(eng: ServingEngine, vocab: int,
                  warm_temp: float = 0.0, max_steps: int = 100_000,
                  requests=None):
    """Compile every shape a measured window can hit, then reset the
    engine's counters: one throwaway admission per prompt bucket (the
    prefill variants + the decode step), the sampling decode/admission
    variants when the traffic samples (same compiled shapes for any
    temperature > 0), and every static live-page bucket of the decode
    step — chunked engines warm the fused-chunk variants instead (paged
    engines recompile per pow2 depth bucket — see
    ServingEngine._live_pages; traffic alone only reaches the buckets
    its depths happen to cross).  Pass the workload's `requests` so the
    sampling variants are warmed exactly when the traffic needs them."""
    if requests is not None:
        warm_temp = warm_temp_for(requests, warm_temp)
    rng = np.random.default_rng(12345)
    for i, b in enumerate(eng.buckets):
        eng.submit(Request(uid=-1 - i,
                           prompt=rng.integers(0, vocab, b, dtype=np.int32),
                           max_new=2, temperature=warm_temp))
    if warm_temp > 0:    # mixed traffic also hits the greedy-only step
        eng.submit(Request(uid=-1 - len(eng.buckets),
                           prompt=rng.integers(0, vocab, eng.buckets[0],
                                               dtype=np.int32),
                           max_new=2))
    eng.run(max_steps=max_steps)
    eng.warm_decode(sample=warm_temp > 0)
    eng.done.clear()
    eng.steps = 0
    eng.decode_seconds = 0.0
    eng.decode_tokens = 0


def warmup_router(router: Router, vocab: int, warm_temp: float = 0.0,
                  max_steps: int = 100_000, requests=None):
    """Warm EVERY replica's prefill buckets and decode live-page variants
    (each replica owns its own jitted callables — nothing is shared), then
    zero the router's timing counters so measured makespans are
    steady-state.  Engines are warmed directly (not through the
    executor), which is safe while no run is in flight; the executor's
    own jitted callables (the sharded group step) are warmed through
    `executor.warm()`.  Pass `requests` to derive warm_temp from the
    actual traffic (see warm_temp_for)."""
    if requests is not None:
        warm_temp = warm_temp_for(requests, warm_temp)
    for eng in router.engines:
        warmup_engine(eng, vocab, warm_temp, max_steps=max_steps)
    router.executor.warm(sample=warm_temp > 0)
    router.reset_counters()


def latency_stats(done: Dict[int, Request]) -> Dict[str, float]:
    """p50/p95 end-to-end latency (submit -> finish) over requests that
    finished OK, split into the two SLO components: TTFT (submit ->
    first emitted token, the queueing + prefill wait a user stares at)
    and TPOT (steady-state seconds per token after the first — the
    streaming rate).  Failed/timed-out requests are counted separately,
    NOT folded into the percentiles: a timed-out request's finish stamp
    is exactly its deadline, so including it reports the SLO ceiling as
    an observed latency and quietly flattens p95 toward the deadline.

    TTFT needs the engine's `first_token` stamp (requests recorded
    before PR 10 carry 0.0) and TPOT additionally needs >= 2 output
    tokens; when no ok request qualifies the respective keys are
    OMITTED rather than reported as an impossible 0.0.

    Raises ValueError when no request finished ok: a silent 0.0
    percentile reads as an impossibly fast pipeline in dashboards —
    same contract as ServingEngine.throughput() (PR 4)."""
    if not done:
        raise ValueError(
            "latency_stats() needs at least one finished request; "
            "drive the engine/router before reading latency percentiles")
    ok = [r for r in done.values() if r.status == "ok"]
    if not ok:
        raise ValueError(
            "latency_stats() needs at least one request with status "
            f"'ok' (got {len(done)} finished, all failed/timed_out); "
            "completion latency of a request that never completed is "
            "not a percentile")
    lat = np.array(sorted(r.finished - r.submitted for r in ok))
    stats = {"p50_s": float(np.percentile(lat, 50)),
             "p95_s": float(np.percentile(lat, 95)),
             "ok_requests": len(ok),
             "failed_requests": sum(r.status == "failed"
                                    for r in done.values()),
             "timed_out_requests": sum(r.status == "timed_out"
                                       for r in done.values())}
    ttft = np.array(sorted(r.first_token - r.submitted for r in ok
                           if r.first_token > 0.0))
    if len(ttft):
        stats["ttft_p50_s"] = float(np.percentile(ttft, 50))
        stats["ttft_p95_s"] = float(np.percentile(ttft, 95))
    tpot = np.array(sorted((r.finished - r.first_token)
                           / (len(r.output) - 1) for r in ok
                           if r.first_token > 0.0 and len(r.output) > 1))
    if len(tpot):
        stats["tpot_p50_s"] = float(np.percentile(tpot, 50))
        stats["tpot_p95_s"] = float(np.percentile(tpot, 95))
    return stats


def run_workload(cfg, params, dsg, requests: List[Request], *,
                 admission: str = "overlap", n_slots: int = 4,
                 max_seq: int = 384, prompt_bucket: int = 256,
                 cache_backend: str = "dense", page_size: int = 16,
                 cache_tokens=None, seed: int = 0, replicas: int = 1,
                 route_policy: str = "least_queue",
                 exec_mode: str = "sequential", dsg_serving=None,
                 fault_tolerance=None, faults=None,
                 decode_chunk: int = 1, prefix_sharing: bool = False,
                 max_steps: int = 100_000) -> Dict[str, float]:
    """Run the request list through one engine (replicas=1, the historical
    path) or a Router over `replicas` engines; returns throughput/latency
    stats.  Warmup triggers every jit compile on every replica first so
    the measurement is steady-state.

    `exec_mode` picks the replica executor (serving/parallel_exec.py):
    "sequential" steps replicas in-process, "threaded" free-runs one
    worker thread per replica, "sharded" fuses the group into one
    vmapped device step.  `dsg_serving` (None | True | DSGServingConfig)
    turns on the serving-side DSG sparsity runtime per engine
    (serving/dsg_runtime.py; every replica owns its own pattern state).
    Router runs add `makespan_s` — MODELED
    data-parallel wall clock (slowest replica's busy time) under the
    sequential executor, MEASURED wall clock under the parallel ones
    (`makespan_measured` records which) — and `parallel_tok_per_s`
    (tokens / makespan) to the stats.

    Fault tolerance (docs/fault_tolerance.md): `fault_tolerance` (None |
    True | dict | FaultToleranceConfig) opts the Router into failure
    containment; `faults` (a ReplicaFault list or a ServingFaultInjector,
    runtime/fault_tolerance.py) injects deterministic chaos — attached
    AFTER warmup so step-keyed faults never fire inside the compile
    pass.  Injecting faults auto-enables default fault tolerance (an
    uncontained kill would just crash the run) and forces the Router
    path.  Chaos runs add failed/timed_out/replica_health stats."""
    engine_kw = dict(n_slots=n_slots, max_seq=max_seq,
                     prompt_bucket=prompt_bucket, admission=admission,
                     cache_backend=cache_backend, page_size=page_size,
                     cache_tokens=cache_tokens, dsg_serving=dsg_serving,
                     decode_chunk=decode_chunk,
                     prefix_sharing=prefix_sharing)
    if faults is not None and fault_tolerance is None:
        fault_tolerance = True
    if (replicas == 1 and exec_mode == "sequential"
            and fault_tolerance is None):
        eng = ServingEngine(cfg, params, dsg, seed=seed, **engine_kw)
        warmup_engine(eng, cfg.vocab, max_steps=max_steps,
                      requests=requests)
        runner, stepper = eng, eng
    else:
        runner = Router(cfg, params, dsg, n_replicas=replicas,
                        policy=route_policy, exec_mode=exec_mode,
                        seed=seed, fault_tolerance=fault_tolerance,
                        **engine_kw)
        warmup_router(runner, cfg.vocab, max_steps=max_steps,
                      requests=requests)
        stepper = None

    injector = None
    if faults is not None:
        injector = (faults if hasattr(faults, "on_step")
                    else ServingFaultInjector(faults))
        injector.attach(runner.engines)
    for r in requests:
        runner.submit(r)
    try:
        t0 = time.perf_counter()
        done = runner.run(max_steps=max_steps)
        wall = time.perf_counter() - t0
    finally:
        if stepper is None:
            # release executor worker threads even when the run raises
            # (e.g. a stalled router) — engines would otherwise stay
            # pinned by parked daemon threads
            runner.close()
    toks = sum(len(r.output) for r in done.values())
    stats = {
        "admission": admission,
        "cache_backend": cache_backend,
        "replicas": replicas,
        "decode_chunk": decode_chunk,
        "prefix_sharing": prefix_sharing,
        "requests": len(done),
        "tokens": toks,
        "truncated": sum(r.truncated for r in done.values()),
        "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        # raises on an empty result set instead of reporting 0.0
        # percentiles — a measured workload that finished nothing is an
        # error, not a very fast run
        **latency_stats(done),
    }
    if stepper is not None:
        stats.update({
            "cache_bytes": int(stepper.backend.resident_bytes(stepper.cache)),
            # decode_tok_per_s() raises before any token decodes, but a
            # request can finish on its admission token alone (max_new=1)
            # with zero decode steps — that run is legal, so guard
            "decode_tok_per_s": stepper.decode_tok_per_s()
                                if stepper.decode_tokens else 0.0,
            "steps": stepper.steps,
        })
        if prefix_sharing:
            stats.update({
                "prefill_cache_hits": stepper.prefill_cache_hits,
                "shared_page_hits": stepper.backend.shared_page_hits,
                "cow_copies": stepper.backend.cow_copies,
                "peak_live_pages": stepper.backend.allocator.peak_live,
            })
    else:
        stats.update({
            "route_policy": runner.policy.name,
            "exec_mode": runner.executor.name,
            "cache_bytes": sum(int(e.backend.resident_bytes(e.cache))
                               for e in runner.engines),
            "decode_tok_per_s": sum(e.decode_tokens
                                    for e in runner.engines)
                                / max(sum(e.decode_seconds
                                          for e in runner.engines), 1e-9),
            # total engine decode steps (what serve.py prints); one router
            # tick steps up to `replicas` engines, reported separately
            "steps": sum(e.steps for e in runner.engines),
            "router_steps": runner.steps,
            "makespan_s": runner.makespan_seconds(),
            "makespan_measured": runner.executor.measured,
            "parallel_tok_per_s": toks / max(runner.makespan_seconds(),
                                             1e-9),
            "per_replica": runner.replica_stats(),
        })
        if runner.ft is not None:
            stats.update({
                "completed_ok": sum(r.status == "ok"
                                    for r in done.values()),
                "failed": sum(r.status == "failed"
                              for r in done.values()),
                "timed_out": sum(r.status == "timed_out"
                                 for r in done.values()),
                "retries": sum(r.retries for r in done.values()),
                "replica_health": [h.state for h in runner.health],
                "faults_fired": (len(injector.log)
                                 if injector is not None else 0),
            })
    return stats
