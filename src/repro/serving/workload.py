"""Synthetic serving workloads + engine measurement harness.

Mixed-length traffic is where overlap admission earns its keep: short and
long prompts (and short and long generations) interleave, so a wave-admission
engine strands free lanes until the whole batch drains while overlap refills
them immediately.  bench_serving.py and `launch/serve.py --workload mixed`
both drive the engine through this module so the numbers agree.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.serving.scheduler import Request, ServingEngine


def mixed_requests(vocab: int, n_requests: int, *, seed: int = 0,
                   prompt_range=(8, 192), max_new_range=(8, 64),
                   eos_id=None) -> List[Request]:
    """Mixed-length synthetic traffic: uniform prompt lengths and
    generation budgets over the given ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        plen = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
        max_new = int(rng.integers(max_new_range[0], max_new_range[1] + 1))
        prompt = rng.integers(0, vocab, plen, dtype=np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=max_new,
                            eos_id=eos_id))
    return reqs


def run_workload(cfg, params, dsg, requests: List[Request], *,
                 admission: str = "overlap", n_slots: int = 4,
                 max_seq: int = 384, prompt_bucket: int = 256,
                 max_steps: int = 100_000) -> Dict[str, float]:
    """Run one engine over the request list; returns throughput/latency
    stats.  A warmup admission+decode over throwaway requests triggers the
    jit compiles first so the measurement is steady-state."""
    eng = ServingEngine(cfg, params, dsg, n_slots=n_slots, max_seq=max_seq,
                        prompt_bucket=prompt_bucket, admission=admission)
    # warmup: compile every prefill bucket + the decode step
    vocab = cfg.vocab
    rng = np.random.default_rng(12345)
    for i, b in enumerate(eng.buckets):
        eng.submit(Request(uid=-1 - i,
                           prompt=rng.integers(0, vocab, b, dtype=np.int32),
                           max_new=2))
    eng.run(max_steps=max_steps)
    eng.done.clear()
    eng.steps = 0

    for r in requests:
        eng.submit(r)
    t0 = time.time()
    done = eng.run(max_steps=max_steps)
    wall = time.time() - t0
    toks = sum(len(r.output) for r in done.values())
    lat = eng.latencies()
    return {
        "admission": admission,
        "requests": len(done),
        "tokens": toks,
        "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "steps": eng.steps,
        "p50_s": float(np.percentile(lat, 50)) if len(lat) else 0.0,
        "p95_s": float(np.percentile(lat, 95)) if len(lat) else 0.0,
    }
