"""Queue-depth-aware front-end router over per-replica serving engines.

At pod scale the slot logic of `ServingEngine` runs once per
data-parallel replica group (the ROADMAP's multi-replica item): every
replica owns a full copy of the serving state — its params view, its
cache backend (dense or paged, with its own BlockAllocator), and its
PRNG stream — and a front-end `Router` decides which replica each
incoming `Request` lands on.  Like Dynasparse's runtime rebalancing work
as dynamic sparsity shifts per-input cost, routing reacts to LIVE state
(queue depth, free lanes, free cache pages), not a static assignment:

  * round_robin  — static cyclic assignment; dispatches unconditionally.
                   The baseline every policy is benchmarked against, and
                   the strawman: it cannot see that one replica drew all
                   the expensive requests.
  * least_queue  — pull-based: a request is dispatched only when some
                   replica has an uncommitted free lane (free_slots >
                   queue_depth), to the replica with the least
                   outstanding work (queued + resident requests).
                   Work-conserving under skewed traffic — fast replicas
                   drain their lanes and pull more work while a slow
                   replica keeps grinding its long generations
                   (benchmarks/bench_router.py gates the speedup).
  * least_pages  — admission-safe: dispatch only to a replica whose
                   cache backend can reserve the request's worst-case
                   page count RIGHT NOW (ServingEngine.can_admit_request),
                   preferring the replica with the most unreserved free
                   pages.  A dispatched request is therefore admitted on
                   the replica's very next step — per-replica admission
                   deferral never triggers (tests/test_router.py pins
                   this).

Requests a policy declines to place wait in the router's own FIFO queue
and are re-offered every step; policies never reorder the queue, so
dispatch is FIFO onto whichever replica the policy picks.

Determinism: each replica is solo-deterministic (greedy decode under
per-row DRS selection is bit-identical to a solo run regardless of lane
or co-residents — pinned since PR 1), so the MERGED result dict keyed by
request uid is invariant to the replica count, the routing policy, AND
the executor under temperature=0.  Sampling draws from per-replica PRNG
streams (replica r seeds at `seed + r`; replica 0 matches a bare
engine), so sampled streams are reproducible for a fixed replica count +
policy under the lockstep executors, but not across configurations (and
not at all under the free-running threaded executor, where placement
follows live timing).

HOW replicas run is a pluggable executor (serving/parallel_exec.py,
`exec_mode=`): "sequential" steps them in-process one after another and
`makespan_seconds()` MODELS the data-parallel wall clock from the
slowest replica's busy time (PR 4's record-then-model discipline);
"threaded" free-runs one worker thread per replica and "sharded" fuses
the replica group into one vmapped device step — under both,
`makespan_seconds()` is the MEASURED wall clock.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax

from repro.analysis.contracts import owned_by, runs_on
from repro.serving.parallel_exec import (EXEC_MODES, ReplicaFailure,
                                         get_executor)
from repro.serving.scheduler import Request, ServingEngine

POLICIES = ("round_robin", "least_queue", "least_pages")

HEALTH_STATES = ("healthy", "suspect", "dead")


@dataclass(frozen=True)
class FaultToleranceConfig:
    """Failover policy for a Router (docs/fault_tolerance.md).

    Passing any config (even the defaults) OPTS IN to fault tolerance:
    replica failures are contained (reclaim + re-dispatch) instead of
    re-raised, and undispatchable requests finish with an explicit
    `failed`/`timed_out` status instead of raising the stall error.
    `Router(fault_tolerance=None)` — the default — keeps the historical
    fail-fast behavior bit-for-bit.

      max_replica_restarts — how many times a failed replica is returned
          to service before it is marked DEAD for good (0 = first
          failure is fatal to the replica).
      max_retries — per-request re-dispatch budget: a request reclaimed
          from a failed replica more than this many times finishes with
          status "failed" instead of being requeued.
      stall_timeout_s — threaded executor only: a replica whose worker
          makes no step progress for this long is marked SUSPECT and
          asked to abort at its next step boundary (None = no stall
          detection; lockstep executors step in-process and cannot
          stall-detect themselves).
    """
    max_replica_restarts: int = 1
    max_retries: int = 2
    stall_timeout_s: Optional[float] = None

    def __post_init__(self):
        if self.max_replica_restarts < 0:
            raise ValueError(f"max_replica_restarts must be >= 0 "
                             f"(got {self.max_replica_restarts})")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0 "
                             f"(got {self.max_retries})")
        if self.stall_timeout_s is not None and self.stall_timeout_s <= 0:
            raise ValueError(f"stall_timeout_s must be positive or None "
                             f"(got {self.stall_timeout_s})")


def as_ft_config(ft) -> Optional[FaultToleranceConfig]:
    """None | True | dict | FaultToleranceConfig -> config or None."""
    if ft is None or isinstance(ft, FaultToleranceConfig):
        return ft
    if ft is True:
        return FaultToleranceConfig()
    if isinstance(ft, dict):
        return FaultToleranceConfig(**ft)
    raise ValueError(f"fault_tolerance must be None, True, a dict, or a "
                     f"FaultToleranceConfig (got {ft!r})")


@dataclass
class ReplicaHealth:
    """Per-replica health state machine: HEALTHY -> SUSPECT -> DEAD.

    HEALTHY replicas are routable.  SUSPECT is the transient stall-
    timeout state: the replica's worker stopped making progress, the
    router has asked its engine to abort, and the abort will surface as
    a failure at the next step boundary — policies already skip it.
    A failure consumes one restart from `max_replica_restarts`; within
    budget the replica returns to HEALTHY (engines stay warm, so a
    restart is just reclaim + re-mark), beyond it the replica is DEAD
    and never routed to again.  `events` records every transition as
    (from_state, to_state, reason) for tests and post-mortems."""
    state: str = "healthy"
    restarts: int = 0                       # restarts consumed so far
    failures: List[str] = field(default_factory=list)
    events: List[tuple] = field(default_factory=list)


class RoutePolicy:
    """Pluggable routing policy: `select` returns the replica index to
    dispatch `req` to, or None to leave it queued at the router until the
    next step (deferral).  Policies read replica introspection only
    (queue_depth/free_slots/free_pages/can_admit_request) — they never
    mutate engine state."""

    name = "abstract"

    def select(self, router: "Router", req: Request) -> Optional[int]:
        raise NotImplementedError


class RoundRobin(RoutePolicy):
    """Static cyclic assignment, blind to load; never defers while a
    routable (healthy) replica exists — unhealthy replicas are skipped,
    keeping the cadence over the survivors."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def select(self, router, req):
        n = len(router.replicas)
        for _ in range(n):
            r = self._next % n
            self._next += 1
            if router.routable(r):
                return r
        return None                    # every replica unhealthy: defer


class LeastQueue(RoutePolicy):
    """Least outstanding work (queued + resident requests) among replicas
    with an uncommitted free lane; ties break on the lowest index so
    dispatch is deterministic."""

    name = "least_queue"

    def select(self, router, req):
        best, best_score = None, None
        for i, eng in enumerate(router.replicas):
            if not router.routable(i):
                continue                   # unhealthy: never dispatch into it
            if eng.free_slots() <= eng.queue_depth():
                continue                   # every free lane already spoken for
            score = eng.queue_depth() + eng.busy_slots()
            if best_score is None or score < best_score:
                best, best_score = i, score
        return best


class LeastPages(RoutePolicy):
    """Most unreserved free cache pages among replicas that can admit the
    request immediately (free lane AND the backend can cover its
    worst-case page reservation).  Dispatch-to-admission is atomic from
    the replica's point of view — its internal deferral path never runs.
    Requires an empty replica queue so a second dispatch cannot ride on
    pages the first one is about to reserve."""

    name = "least_pages"

    def select(self, router, req):
        best, best_pages = None, None
        for i, eng in enumerate(router.replicas):
            if not router.routable(i):
                continue
            if eng.queue_depth() or not eng.can_admit_request(req):
                continue
            pages = eng.free_pages()
            if best_pages is None or pages > best_pages:
                best, best_pages = i, pages
        return best


def get_policy(name: Union[str, RoutePolicy]) -> RoutePolicy:
    """Factory: policy name -> fresh policy instance (round_robin carries
    a cursor, so instances are per-router).  Objects with a `select`
    method pass through."""
    if hasattr(name, "select"):
        return name
    if name == "round_robin":
        return RoundRobin()
    if name == "least_queue":
        return LeastQueue()
    if name == "least_pages":
        return LeastPages()
    raise ValueError(f"unknown route policy {name!r}; "
                     f"expected one of {POLICIES}")


@owned_by("router", "queue", "dispatch_log", "steps", "health", "failed",
          "fail_log")
class Router:
    """Front-end over N independent `ServingEngine` replicas.

    Construction mirrors `ServingEngine` — `**engine_kw` is forwarded to
    every replica (`n_slots`, `max_seq`, `prompt_bucket`, `admission`,
    `cache_backend`, `page_size`, `cache_tokens`, ...).  `cache_backend`
    must be a name, not a backend instance: a `PagedBackend` manages one
    live handle, so each replica builds its own.  `param_views` optionally
    supplies one params pytree per replica (e.g. per-device placements of
    the same weights); by default all replicas share the caller's pytree —
    data-parallel replicas hold identical weights either way.

    `exec_mode` picks how the replica group executes
    (serving/parallel_exec.py): "sequential" (default, PR 4's stepped
    in-process behavior, modeled makespan), "threaded" (one free-running
    worker thread per replica, measured makespan), or "sharded" (one
    vmapped device step over the stacked replica group, measured
    makespan; `mesh=` optionally lays the stack over a `replicas` mesh
    axis).  Under "threaded", when multiple local devices exist and no
    `param_views` are given, each replica's params are placed on its own
    device (`jax.local_devices()[r % n]`) so replica steps overlap on
    real hardware instead of queueing on one device.

    `fault_tolerance` (None | True | dict | FaultToleranceConfig) opts
    into per-replica health tracking (healthy/suspect/dead), restart
    budgets, deterministic failover (reclaimed requests replay from
    their prompts on survivors — bitwise identical at temperature 0),
    per-request deadlines, and bounded retries; `None` (default) keeps
    the historical fail-fast contract.  See docs/fault_tolerance.md.

    Drive it exactly like an engine:

        router = Router(cfg, params, dsg, n_replicas=4,
                        policy="least_queue", n_slots=4)
        for r in requests: router.submit(r)
        done = router.run()        # {uid: Request}, replica-count AND
                                   # executor invariant at temperature=0
    """

    def __init__(self, cfg, params, dsg, *, n_replicas: int = 1,
                 policy: Union[str, RoutePolicy] = "least_queue",
                 param_views: Optional[Sequence] = None, seed: int = 0,
                 exec_mode: str = "sequential", mesh=None,
                 fault_tolerance=None, **engine_kw):
        if n_replicas < 1:
            raise ValueError("router needs at least one replica")
        if hasattr(engine_kw.get("cache_backend"), "make"):
            raise ValueError(
                "pass cache_backend by name: backend instances manage one "
                "live handle and cannot be shared across replicas")
        if param_views is not None and len(param_views) != n_replicas:
            raise ValueError(f"param_views must supply one params pytree "
                             f"per replica ({n_replicas})")
        if exec_mode not in EXEC_MODES:
            # executor instances are bound to THEIR engines; the router
            # builds its own, so it only takes mode names (swap
            # router.executor after construction for custom strategies)
            raise ValueError(f"unknown exec mode {exec_mode!r}; "
                             f"expected one of {EXEC_MODES}")
        self.policy = get_policy(policy)
        dsg_views = [dsg] * n_replicas
        if (exec_mode == "threaded" and param_views is None
                and jax.local_device_count() > 1):
            # data-parallel placement: replica r's weights (and therefore
            # its jitted steps — computation follows committed inputs)
            # live on device r, so worker threads overlap on hardware
            devs = jax.local_devices()
            param_views = [jax.device_put(params, devs[r % len(devs)])
                           for r in range(n_replicas)]
            if dsg is not None:
                dsg_views = [jax.device_put(dsg, devs[r % len(devs)])
                             for r in range(n_replicas)]
        self.engines: List[ServingEngine] = [
            ServingEngine(cfg,
                          param_views[r] if param_views is not None
                          else params,
                          dsg_views[r], seed=seed + r, **engine_kw)
            for r in range(n_replicas)]
        self.executor = get_executor(exec_mode, self.engines, mesh=mesh)
        # the dispatch + introspection surface policies see: executor-
        # owned proxies (attribute access forwards to the engines)
        self.replicas = self.executor.proxies
        self.queue: collections.deque = collections.deque()
        self.dispatch_log: List[tuple] = []     # (uid, replica index)
        self.steps = 0
        # fault tolerance (docs/fault_tolerance.md): None keeps the
        # historical fail-fast behavior — failures re-raise, stalls raise
        self.ft = as_ft_config(fault_tolerance)
        self.health = [ReplicaHealth() for _ in range(n_replicas)]
        self.failed: Dict[int, Request] = {}    # failed/timed_out, by uid
        self.fail_log: List[tuple] = []         # (uid, status, reason)
        for r, eng in enumerate(self.engines):
            eng.replica_index = r               # failure attribution

    # -- request flow --------------------------------------------------------

    @runs_on("router")
    def submit(self, req: Request):
        req.submitted = req.submitted or time.perf_counter()
        self.queue.append(req)

    def routable(self, i: int) -> bool:
        """Whether policies may dispatch to replica `i`.  Without fault
        tolerance health is never mutated, so every replica stays
        routable and policies behave exactly as before."""
        return self.health[i].state == "healthy"

    @runs_on("router")
    def _dispatch(self):
        """Offer the queue head to the policy until it defers (FIFO:
        requests are never dispatched around a deferred head)."""
        while self.queue:
            r = self.policy.select(self, self.queue[0])
            if r is None:
                return
            req = self.queue.popleft()
            self.replicas[r].submit(req)
            self.dispatch_log.append((req.uid, r))

    @runs_on("router")
    def step(self):
        """One lockstep router tick: dispatch what the policy will place,
        then have the executor advance every replica that has work one
        step (per-replica time lands in the executor's busy_seconds).
        Free-running executors have no tick — drive them with
        run()/drain()."""
        if not self.executor.lockstep:
            raise RuntimeError(
                f"executor {self.executor.name!r} free-runs replicas from "
                f"worker threads; drive it with run() or drain(), not "
                f"step()")
        self._expire_deadlines()
        self._dispatch()
        active = [i for i, eng in enumerate(self.engines)
                  if self.executor.has_work(eng)]
        if active:
            try:
                self.executor.step_all(active)
            except ReplicaFailure as err:
                # fault tolerance off: re-raise (str(err) carries the
                # cause message, so callers matching on it still work)
                if not self._handle_replica_failure(err):
                    raise
        elif self.queue:
            # every replica is idle yet the policy still defers the head:
            # retirements can never free what it is waiting for (e.g. a
            # paged pool smaller than one request's reservation) — the
            # router analogue of the engine's stalled-admission error
            if self.ft is not None:
                self._fail_undispatchable()
            else:
                raise RuntimeError(
                    f"router stalled: {len(self.queue)} queued request(s) "
                    f"undispatchable by policy {self.policy.name!r} while "
                    f"all replicas are idle; raise cache_tokens or lower "
                    f"max_new/prompt_bucket")
        self.steps += 1

    # -- fault tolerance (docs/fault_tolerance.md) ---------------------------

    @runs_on("router")
    def _transition(self, i: int, state: str, reason: str):
        h = self.health[i]
        h.events.append((h.state, state, reason))
        h.state = state

    @runs_on("router")
    def _finish_failed(self, req: Request, status: str, reason: str):
        """Terminal non-ok completion: the request surfaces in done()
        with an explicit status instead of hanging the drain loop."""
        req.status = status
        req.finished = time.perf_counter()
        self.failed[req.uid] = req
        self.fail_log.append((req.uid, status, reason))

    @runs_on("router")
    def _expire_deadlines(self):
        """Fail out router-queued requests whose deadline passed.  A
        request already admitted to a lane is never interrupted — it
        either completes (cheaper than eviction this close to done) or
        gets its deadline re-checked at reclaim time after a failure."""
        if self.ft is None:
            return
        now = time.perf_counter()
        expired = [r for r in self.queue
                   if r.deadline_s is not None
                   and now - r.submitted > r.deadline_s]
        for req in expired:
            self.queue.remove(req)
            self._finish_failed(req, "timed_out",
                                f"deadline {req.deadline_s}s expired in "
                                f"router queue")

    @runs_on("router")
    def _handle_replica_failure(self, err: ReplicaFailure) -> bool:
        """Contain one replica failure; False when fault tolerance is
        off (the caller re-raises)."""
        if self.ft is None:
            return False
        self._on_replica_failure(err.index, err.cause)
        return True

    @runs_on("router")
    def _on_replica_failure(self, i: int, cause: BaseException):
        """The failover sequence: reclaim the failed replica's queued +
        in-flight requests (pages/lanes freed via ServingEngine.reset),
        decide the replica's fate against its restart budget, and requeue
        the reclaimed requests at the FRONT of the router queue (they
        were dispatched first; FIFO order is preserved).  Each reclaimed
        request replays FROM ITS PROMPT: the partial output is discarded,
        so at temperature 0 the re-decoded stream is bit-identical to an
        uninterrupted run — the paper's determinism property is what
        makes failover this cheap."""
        h = self.health[i]
        h.failures.append(str(cause))
        reclaimed = self.engines[i].reset()
        if h.restarts < self.ft.max_replica_restarts:
            h.restarts += 1
            self._transition(
                i, "healthy",
                f"restarted ({h.restarts}/{self.ft.max_replica_restarts})"
                f" after: {cause}")
        else:
            self._transition(i, "dead",
                             f"restart budget exhausted after: {cause}")
        now = time.perf_counter()
        # reversed so appendleft lands them at the head in reclaim order
        for req in reversed(reclaimed):
            req.retries += 1
            req.output.clear()           # replay from the prompt
            req.started = 0.0
            req.first_token = 0.0        # TTFT re-stamps on the survivor
            if (req.deadline_s is not None
                    and now - req.submitted > req.deadline_s):
                self._finish_failed(req, "timed_out",
                                    f"deadline {req.deadline_s}s expired "
                                    f"during failover from replica {i}")
            elif req.retries > self.ft.max_retries:
                self._finish_failed(req, "failed",
                                    f"retry budget exhausted "
                                    f"({self.ft.max_retries}) after "
                                    f"replica {i} failed")
            else:
                self.queue.appendleft(req)

    @runs_on("router")
    def _on_replica_stall(self, i: int):
        """Stall-timeout containment (threaded executor): the worker is
        stuck inside a step and cannot be killed safely, so mark the
        replica SUSPECT (policies stop routing to it) and ask its engine
        to abort — the EngineAborted raise at the next step boundary
        funnels into the standard failure path.  A worker wedged forever
        inside a single device call never reaches that boundary; its
        requests stay lost until process restart (documented limit)."""
        if self.ft is None or self.health[i].state != "healthy":
            return
        timeout = self.ft.stall_timeout_s
        self._transition(i, "suspect",
                         f"no step progress for {timeout}s")
        self.engines[i].abort = True

    @runs_on("router")
    def _fail_undispatchable(self):
        """Graceful degradation: every replica is idle yet the policy
        still defers — retirements can never unblock the head.  With no
        routable replica left every queued request fails; otherwise only
        the head does (the next head may be placeable)."""
        if not any(self.routable(i) for i in range(len(self.engines))):
            while self.queue:
                self._finish_failed(self.queue.popleft(), "failed",
                                    "no routable replica (all dead)")
        elif self.queue:
            self._finish_failed(self.queue.popleft(), "failed",
                                f"undispatchable by policy "
                                f"{self.policy.name!r} with all replicas "
                                f"idle")

    @runs_on("router")
    def reset_health(self):
        """Revive every replica (benchmark/test repeats after a chaos
        run): health back to HEALTHY, failure/event/fail logs cleared.
        Engines keep their compiled callables — reviving is free."""
        for h in self.health:
            h.state = "healthy"
            h.restarts = 0
            h.failures.clear()
            h.events.clear()
        self.failed.clear()
        self.fail_log.clear()

    def _busy(self) -> bool:
        return bool(self.queue) or any(
            self.executor.has_work(eng) for eng in self.engines)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Drive every submitted request to completion and return the
        merged `{uid: Request}` results.  Lockstep executors are ticked
        through `step()`; free-running executors own the loop via
        `executor.drive()`."""
        if self.executor.lockstep:
            while self._busy() and self.steps < max_steps:
                self.step()
        elif self._busy():
            self.executor.drive(self, max_steps)
        return self.done()

    def drain(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Finish every in-flight and queued request (no new submissions
        assumed): dispatches the remaining router queue and steps every
        replica until its lanes retire — run() under its retirement-
        draining name, as on the engine."""
        return self.run(max_steps=max_steps)

    def done(self) -> Dict[int, Request]:
        """Merged completed requests across replicas, keyed by uid — the
        replica-count-invariant result surface (uids must be unique
        across the submitted set).  Includes requests the fault-tolerance
        layer finished with status "failed"/"timed_out": every submitted
        request surfaces exactly once, check `req.status`."""
        out: Dict[int, Request] = dict(self.failed)
        for eng in self.engines:
            out.update(eng.done)
        return out

    def close(self):
        """Release executor resources (the threaded executor's worker
        threads).  Safe to call more than once; the router remains
        usable — workers restart at the next run()."""
        self.executor.close()

    # -- introspection / stats ----------------------------------------------

    def queue_depth(self) -> int:
        """Router-level queue only; per-replica queues are the replicas'."""
        return len(self.queue)

    @property
    def busy_seconds(self) -> List[float]:
        """Per-replica accumulated stepping time (executor-owned)."""
        return self.executor.busy_seconds

    def makespan_seconds(self) -> float:
        """The data-parallel wall clock.  MEASURED (executor wall time)
        when the live executor truly overlaps replicas (threaded,
        sharded); otherwise MODELED as the slowest replica's accumulated
        busy time — under the sequential executor replicas are stepped
        one after another in-process, so the max busy time is what N
        truly parallel replicas would take."""
        if self.executor.measured:
            return self.executor.wall_seconds
        return max(self.executor.busy_seconds)

    def throughput(self) -> float:
        """Merged end-to-end tok/s (first admission -> last finish across
        all replicas); raises ValueError before any request finishes,
        matching ServingEngine.throughput()."""
        done = self.done()
        if not done:
            raise ValueError(
                "throughput() needs at least one finished request; "
                "run the router (or drain()) before reading stats")
        toks = sum(len(r.output) for r in done.values())
        t0 = min(r.started or r.submitted for r in done.values())
        t1 = max(r.finished for r in done.values())
        return toks / max(t1 - t0, 1e-9)

    @runs_on("router")
    def reset_counters(self):
        """Zero timing/step counters after warmup so measured windows are
        steady-state (the router analogue of warmup_engine's reset)."""
        self.steps = 0
        self.executor.reset_timing()
        self.dispatch_log.clear()

    def replica_stats(self) -> List[dict]:
        """Per-replica snapshot: executor busy time plus the engine's own
        step/token/queue counters — what bench_router and serve.py
        report."""
        return [{
            "replica": i,
            "busy_s": self.executor.busy_seconds[i],
            "steps": eng.steps,
            "decode_tokens": eng.decode_tokens,
            "finished": len(eng.done),
            "queue_depth": eng.queue_depth(),
            "free_slots": eng.free_slots(),
            "free_pages": eng.free_pages(),
            "health": self.health[i].state,
            "restarts": self.health[i].restarts,
        } for i, eng in enumerate(self.engines)]
