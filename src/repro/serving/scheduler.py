"""Continuous-batching serving scheduler with overlap admission.

Fixed-slot continuous batching (vLLM-style, static shapes for XLA): the
engine keeps `n_slots` decode lanes and admits a new prompt into ANY free
lane on ANY step.  Admission prefills the prompt against a throwaway
1-lane dense cache and splices it into the live cache through a pluggable
KV-cache backend (serving/kv_cache.py):

  * cache_backend="dense" — today's worst-case (L, n_slots, Smax, Kv, D)
    layout; the equivalence baseline.
  * cache_backend="paged" — fixed-size pages + per-lane page table + host
    free-list allocator; lanes allocate pages as `pos` grows and return
    them on retirement, so short requests stop paying Smax memory
    (benchmarks/bench_paged_cache.py measures the resident-bytes drop).

Per-slot position counters stay honest (the decode step takes a per-lane
position vector), retirement is per-slot on EOS-after-emit / max_new /
max_seq, and retired lanes are masked out of sampling.  Sampling runs
INSIDE the jitted decode step: per-lane temperature / nucleus top-p with
a per-(step, lane) PRNG key, falling back to greedy argmax for
temperature=0 lanes, so decode stays a single device dispatch.

Prompt lengths are bucketed (DEFAULT_BUCKETS, capped at `prompt_bucket`)
so admission compiles one prefill per bucket — a small fixed set of
shapes; the decode step compiles exactly once.  Prompts longer than the
largest bucket keep only their last `bucket` tokens; the request is
flagged `truncated=True` and the engine warns once.

`admission="wave"` preserves the old drain-then-refill policy (admit only
when every lane is free) as a benchmark baseline — bench_serving.py
measures the overlap speedup against it on mixed-length traffic.

This is the single-host engine; at pod scale the same slot logic runs
per data-parallel replica group with the model sharded over 'model'
(the decode step is already the dry-run-verified sharded function).
"""
from __future__ import annotations

import collections
import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import exempt, owned_by, runs_on
from repro.models import api
from repro.serving import dsg_runtime, kv_cache
from repro.serving.kv_cache import CacheHandle

DEFAULT_BUCKETS = (16, 32, 64, 96, 128, 192, 256)


def bucket_sizes(prompt_bucket: int, max_seq: int,
                 buckets: Optional[Sequence[int]] = None) -> tuple:
    """The prompt buckets an engine will compile: candidate sizes capped
    at prompt_bucket and at max_seq - 1 (a prompt filling every cache
    position would leave no decode headroom).  Exposed so pool-sizing
    code (benchmarks/bench_paged_cache.py) derives the same largest
    bucket as the engine's admission path."""
    cap = min(prompt_bucket, max_seq - 1)
    bs = buckets if buckets is not None else DEFAULT_BUCKETS
    return tuple(sorted({min(b, cap) for b in bs}))

def live_page_bound(max_pos: int, page_size: int, max_pages: int) -> int:
    """Static paged-decode walk bound covering a batch whose deepest lane
    writes at max_pos: pages needed, rounded up to a power of two so the
    decode step compiles at most log2(max_pages) variants instead of one
    per depth, capped at the page-table width."""
    need = max_pos // page_size + 1
    return min(1 << (need - 1).bit_length(), max_pages)


def live_page_buckets(max_pages: int) -> tuple:
    """Every bound live_page_bound can return for a given table width —
    the set warm_decode pre-compiles and traffic models enumerate."""
    return tuple(sorted({min(1 << i, max_pages)
                         for i in range(max_pages.bit_length() + 1)}))


_ADMIT_SALT = 0xADA117   # folds admission PRNG keys off the decode stream

#: Terminal request states.  "ok" is stamped at retirement; "failed" and
#: "timed_out" are stamped by the Router's fault-tolerance layer
#: (serving/router.py) — an engine on its own never fails a request.
REQUEST_STATUSES = ("pending", "ok", "failed", "timed_out")


class EngineAborted(RuntimeError):
    """Raised by an engine whose `abort` flag was set: the stall-timeout
    containment path (serving/router.py) cannot kill a thread stuck
    inside a device call, so it asks the engine to abandon its in-flight
    state at the NEXT step boundary — the raise funnels the replica into
    the standard failure/reclaim path."""


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0         # 0 -> greedy argmax
    top_p: float = 1.0               # nucleus mass kept when sampling
    deadline_s: Optional[float] = None   # max submit->finish wait (router)
    # filled by the engine (time.perf_counter() stamps — monotonic, for
    # duration math only; NTP steps would corrupt wall-clock latencies):
    output: List[int] = field(default_factory=list)
    truncated: bool = False          # prompt exceeded the largest bucket
    submitted: float = 0.0
    started: float = 0.0             # admission time (first compute)
    first_token: float = 0.0         # first output token observed (TTFT)
    finished: float = 0.0
    status: str = "pending"          # one of REQUEST_STATUSES
    retries: int = 0                 # failover re-dispatches consumed


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                      # next write position in the cache

    @property
    def free(self) -> bool:
        return self.req is None


@dataclass
class StepPlan:
    """Host-built operands for one jitted decode dispatch.

    `ServingEngine.begin_step()` runs the host half of a decode step
    (admission, emit bookkeeping, page-table growth) and returns a plan;
    the device half dispatches the jitted decode with the plan's operands
    and `commit_step()` records the result (retirement, counters).  The
    split exists so replica executors (serving/parallel_exec.py) can
    batch the device half across engines — the sharded executor stacks
    the operands of several plans along a leading replica axis and runs
    one vmapped decode — while `ServingEngine.step()` stays the
    single-engine begin -> dispatch -> commit composition.
    """
    active: List[int]                 # slot indices decoding this step
    donor: int                        # active lane free lanes mirror
    tok: np.ndarray                   # (n_slots,) int32 decode inputs
    pos: np.ndarray                   # (n_slots,) int32 write positions
    free_mask: np.ndarray             # (n_slots,) bool
    temps: np.ndarray                 # (n_slots,) float32
    top_ps: np.ndarray                # (n_slots,) float32
    live_pages: int                   # static paged walk bound (0 = dense)
    sample: bool                      # any lane with temperature > 0
    # fused-chunk dispatch (decode_chunk > 1): `chunk` micro-steps run in
    # one device dispatch, with per-lane EOS / emit-budget freezing on
    # device, so begin_step emits nothing and commit_chunk lags a full
    # chunk behind.  eos_ids uses -1 for "no stop token".
    chunk: int = 1
    eos_ids: Optional[np.ndarray] = None   # (n_slots,) int32
    emit_left: Optional[np.ndarray] = None  # (n_slots,) int32 budget
    refresh: bool = False             # DSG: collect scores at last micro-step


def _restore_table(data, c):
    # the host mirror is the source of truth for the page table;
    # the lane-mirrored view must not escape the step
    if c.kind != "paged":
        return data
    return {**data, "page_table": c.data["page_table"]}


def make_decode_fns(cfg):
    """Build the (greedy, sample) decode-step callables the engine jits.

    Module-level (rather than closures in `ServingEngine.__init__`) so
    the sharded replica executor can vmap THE SAME step bodies over a
    leading replica axis — one definition, two compilation strategies,
    no drift between the per-engine and batched paths.
    """
    def _decode_greedy(p, d, tok, c, pos, free_mask, donor, live_pages):
        view = kv_cache.decode_view(c, free_mask, donor)
        logits, data = api.decode_step(p, d, cfg, tok, view, pos,
                                       live_pages=live_pages)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, CacheHandle(_restore_table(data, c), c.kind,
                                c.page_size)

    def _decode_sample(p, d, tok, c, pos, free_mask, donor, live_pages,
                       key, step, temps, top_ps):
        view = kv_cache.decode_view(c, free_mask, donor)
        logits, data = api.decode_step(p, d, cfg, tok, view, pos,
                                       live_pages=live_pages)
        keys = jax.random.split(jax.random.fold_in(key, step),
                                tok.shape[0])
        nxt = sample_tokens(logits, keys, temps, top_ps)
        return nxt, CacheHandle(_restore_table(data, c), c.kind,
                                c.page_size)

    return _decode_greedy, _decode_sample


def make_dsg_decode_fns(cfg):
    """DSG-serving decode-step variants (engines with a DSGRuntime):
    the make_decode_fns bodies plus (a) the group-CSR selection operand
    `csr` = {'idx': (L, B, K), 'counts': (L, B)} — free lanes mirror the
    donor's rows in-jit (dsg_runtime.mirror_csr) so paged duplicate K/V
    writes stay bit-identical — and (b) a python-static `refresh` flag
    that additionally returns each layer's DRS group scores of this
    step's FFN inputs (None otherwise); the runtime rewrites due lanes'
    patterns from them AFTER the step, off the measured decode window.
    K is static (pow2 active-group bound), so the decode compiles
    (bounds x refresh) variants, all pre-compiled by warm_decode."""
    from repro.serving.dsg_runtime import mirror_csr

    def _dsg_greedy(p, d, tok, c, pos, free_mask, donor, live_pages, csr,
                    refresh):
        view = kv_cache.decode_view(c, free_mask, donor)
        csr_m = mirror_csr(csr, free_mask, donor)
        out = api.decode_step(p, d, cfg, tok, view, pos,
                              live_pages=live_pages, ffn_csr=csr_m,
                              collect_drs_scores=refresh)
        if refresh:
            logits, data, scores = out
        else:
            (logits, data), scores = out, None
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, CacheHandle(_restore_table(data, c), c.kind,
                                 c.page_size), scores)

    def _dsg_sample(p, d, tok, c, pos, free_mask, donor, live_pages, csr,
                    key, step, temps, top_ps, refresh):
        view = kv_cache.decode_view(c, free_mask, donor)
        csr_m = mirror_csr(csr, free_mask, donor)
        out = api.decode_step(p, d, cfg, tok, view, pos,
                              live_pages=live_pages, ffn_csr=csr_m,
                              collect_drs_scores=refresh)
        if refresh:
            logits, data, scores = out
        else:
            (logits, data), scores = out, None
        keys = jax.random.split(jax.random.fold_in(key, step),
                                tok.shape[0])
        nxt = sample_tokens(logits, keys, temps, top_ps)
        return (nxt, CacheHandle(_restore_table(data, c), c.kind,
                                 c.page_size), scores)

    return _dsg_greedy, _dsg_sample


def make_chunked_decode_fns(cfg, chunk: int, max_seq: int):
    """Build the (greedy, sample) FUSED decode-chunk callables: `chunk`
    decode steps scanned inside one jitted dispatch, so the per-token
    host sync (the dispatch-bound wall BENCH_paged_decode.json measures)
    is paid once per chunk instead of once per token.

    The scan carry keeps (tok, pos, done, emit_left, cache) on device.
    Per micro-step, lanes whose done bit is set (initially the free
    lanes; later any lane that hit EOS / its max_new budget / max_seq)
    mirror the first live lane exactly like the chunk=1 donor path —
    `jnp.argmin(done)` re-picks the donor every micro-step because the
    chunk=1 donor (first active lane) can itself finish mid-chunk.  A
    frozen lane's writes are donor duplicates (paged) or overwritten at
    readmission (dense), identical to the chunk=1 free-lane contract.

    Outputs: `blk` (chunk, n_slots) int32 — the token each lane emitted
    at each micro-step (its decode INPUT, matching begin_step's
    emit-before-decode order at chunk=1) — and `flags` (chunk, n_slots)
    bool marking which entries are real.  A lane's flag column is a
    monotone prefix: done never unsets, so the host takes `blk[:n, i]`.
    The final carry's tok is the lane's pending next-step token.

    The sample variant folds the key schedule as (seed, step0 + k,
    lane) — bitwise the per-step schedule, so a sampled lane's stream
    is invariant to the chunk size AS LONG AS its admission step and
    `_draws` count match (chunked scheduling admits at chunk boundaries,
    which shifts admission timing under load; temperature-0 streams are
    unconditionally chunk-invariant).
    """
    def _make(sample):
        def fn(p, d, tok, c, pos, done, emit_left, eos_ids, live_pages,
               *extra):
            if sample:
                key, step0, temps, top_ps = extra

            def body(carry, k):
                tok, pos, done, left, c = carry
                donor = jnp.argmin(done)      # first live lane (False < True)
                tok_in = jnp.where(done, tok[donor], tok)
                pos_in = jnp.where(done, pos[donor], pos)
                view = kv_cache.decode_view(c, done, donor)
                logits, data = api.decode_step(p, d, cfg, tok_in[:, None],
                                               view, pos_in,
                                               live_pages=live_pages)
                if sample:
                    keys = jax.random.split(jax.random.fold_in(key, k),
                                            tok.shape[0])
                    nxt = sample_tokens(logits, keys, temps, top_ps)
                else:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                live = ~done
                fin = live & (((eos_ids >= 0) & (tok_in == eos_ids))
                              | (left <= 1) | (pos_in + 1 >= max_seq))
                c = CacheHandle(_restore_table(data, c), c.kind,
                                c.page_size)
                carry = (jnp.where(live, nxt, tok),
                         jnp.where(live, pos_in + 1, pos),
                         done | fin,
                         jnp.where(live, left - 1, left), c)
                return carry, (tok, live)

            xs = (step0 + jnp.arange(chunk)) if sample else None
            carry0 = (tok, pos, done, emit_left, c)
            (tok_f, _, _, _, c_f), (blk, flags) = jax.lax.scan(
                body, carry0, xs, length=chunk)
            return blk, flags, tok_f, c_f
        return fn

    return _make(False), _make(True)


def make_chunked_dsg_decode_fns(cfg, chunk: int, max_seq: int):
    """DSG variants of make_chunked_decode_fns: the CSR pattern operand
    is CONSTANT across the chunk (the engine enforces refresh_interval %
    chunk == 0, and lanes admit at chunk boundaries, so a refresh-due
    point can only land on the LAST micro-step — the same token index at
    which the chunk=1 cadence fires).  The last micro-step runs outside
    the scan with the python-static `refresh` flag so it can return that
    step's DRS group scores for the host-side pattern rewrite."""
    from repro.serving.dsg_runtime import mirror_csr

    def _make(sample):
        def fn(p, d, tok, c, pos, done, emit_left, eos_ids, live_pages,
               csr, *extra):
            if sample:
                key, step0, temps, top_ps, refresh = extra
            else:
                (refresh,) = extra

            def micro(carry, k, collect):
                tok, pos, done, left, c = carry
                donor = jnp.argmin(done)
                tok_in = jnp.where(done, tok[donor], tok)
                pos_in = jnp.where(done, pos[donor], pos)
                view = kv_cache.decode_view(c, done, donor)
                csr_m = mirror_csr(csr, done, donor)
                out = api.decode_step(p, d, cfg, tok_in[:, None], view,
                                      pos_in, live_pages=live_pages,
                                      ffn_csr=csr_m,
                                      collect_drs_scores=collect)
                if collect:
                    logits, data, scores = out
                else:
                    (logits, data), scores = out, None
                if sample:
                    keys = jax.random.split(jax.random.fold_in(key, k),
                                            tok.shape[0])
                    nxt = sample_tokens(logits, keys, temps, top_ps)
                else:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                live = ~done
                fin = live & (((eos_ids >= 0) & (tok_in == eos_ids))
                              | (left <= 1) | (pos_in + 1 >= max_seq))
                c = CacheHandle(_restore_table(data, c), c.kind,
                                c.page_size)
                carry = (jnp.where(live, nxt, tok),
                         jnp.where(live, pos_in + 1, pos),
                         done | fin,
                         jnp.where(live, left - 1, left), c)
                return carry, (tok, live), scores

            def body(carry, k):
                carry, ys, _ = micro(carry, k, False)
                return carry, ys

            xs = (step0 + jnp.arange(chunk - 1)) if sample else None
            carry = (tok, pos, done, emit_left, c)
            carry, (blk, flags) = jax.lax.scan(body, carry, xs,
                                               length=chunk - 1)
            k_last = (step0 + chunk - 1) if sample else 0
            carry, (tok_l, live_l), scores = micro(carry, k_last, refresh)
            blk = jnp.concatenate([blk, tok_l[None]], axis=0)
            flags = jnp.concatenate([flags, live_l[None]], axis=0)
            tok_f, _, _, _, c_f = carry
            return blk, flags, tok_f, c_f, scores
        return fn

    return _make(False), _make(True)


def sample_tokens(logits: jax.Array, keys: jax.Array, temps: jax.Array,
                  top_ps: jax.Array) -> jax.Array:
    """Per-lane temperature + nucleus sampling, jit-friendly.

    logits (B, V), keys (B, 2) per-lane PRNG keys, temps/top_ps (B,).
    Lanes with temperature 0 take the argmax; the rest sample from the
    smallest prefix of the sorted distribution whose mass reaches top_p
    (the crossing token is kept, so top-1 always survives).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / jnp.maximum(temps, 1e-6)[:, None]
    srt = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(srt, axis=-1)
    keep = (jnp.cumsum(probs, axis=-1) - probs) < top_ps[:, None]
    keep = keep.at[:, 0].set(True)     # top-1 survives even top_p == 0
    kth = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
    lg = jnp.where(lg >= kth, lg, -jnp.inf)
    samp = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)


@owned_by("worker", "queue", "done", "slots", "cache", "steps",
          "decode_seconds", "decode_tokens", "_next_tok", "_draws",
          "_warned_truncation", "_prefill_cache", "prefill_cache_hits")
class ServingEngine:
    """Continuous batching over a fixed slot count.

    Static-shape discipline: a prompt is right-aligned into the smallest
    length bucket that holds it (shorter prompts left-padded), so there is
    one prefill computation per bucket and ONE decode computation to
    compile.  Each admission runs a 1-lane prefill and splices the result
    into the live batched cache via the backend — active lanes' K/V bytes
    are never touched, and under per-row DRS selection
    (threshold_mode="topk") their outputs are bit-identical to a solo run
    AND across cache backends (see tests/test_serving_overlap.py).  With
    the paper's inter-sample threshold sharing (threshold_mode="shared")
    all lanes couple to batch row 0's scores by design; the engine keeps
    that row meaningful by mirroring idle lanes onto an active one.

    The paged backend reserves a request's worst-case page count
    (min(bucket + max_new, max_seq)) at admission, so page-table growth
    during decode can never run out; a pool with too few free pages defers
    admission until retirements return pages.
    """

    def __init__(self, cfg, params, dsg, *, n_slots: int = 4,
                 max_seq: int = 256, prompt_bucket: int = 64,
                 buckets: Optional[Sequence[int]] = None,
                 admission: str = "overlap",
                 cache_backend: Union[str, object] = "dense",
                 page_size: int = 16, cache_tokens: Optional[int] = None,
                 seed: int = 0, dsg_serving=None, decode_chunk: int = 1,
                 prefix_sharing: bool = False):
        if admission not in ("overlap", "wave"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if decode_chunk < 1:
            raise ValueError(
                f"decode_chunk must be >= 1 (got {decode_chunk})")
        self.decode_chunk = decode_chunk
        self.cfg = cfg
        self.params = params
        self.dsg = dsg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_size = page_size
        # a prompt filling all max_seq positions would admit a lane with
        # zero decode headroom (its first decode write lands out of cache
        # range), so the largest bucket always leaves one position free
        self.prompt_bucket = min(prompt_bucket, max_seq - 1)
        self.buckets = bucket_sizes(prompt_bucket, max_seq, buckets)
        self.admission = admission
        self.queue: collections.deque = collections.deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.done: Dict[int, Request] = {}
        self.steps = 0
        self.decode_seconds = 0.0     # time inside jitted decode steps
        self.decode_tokens = 0        # tokens emitted by those steps
        self._draws = 0               # admission PRNG counter
        self._warned_truncation = False
        self._base_key = jax.random.PRNGKey(seed)
        # fault-tolerance surface (serving/router.py, runtime/
        # fault_tolerance.py).  `abort` is a benign cross-thread flag: the
        # router sets it (stall-timeout containment) and the engine's own
        # worker observes it at the next step boundary — a plain bool
        # store/load under the GIL, never read-modify-written.
        self.replica_index = 0        # set by the Router (attribution)
        self.fault_injector = None    # ServingFaultInjector (chaos runs)
        self.abort = False

        self.backend = (cache_backend if hasattr(cache_backend, "make")
                        else kv_cache.get_backend(
                            cache_backend, page_size=page_size,
                            total_tokens=cache_tokens,
                            prefix_sharing=prefix_sharing))
        # copy-on-write shared-prefix reuse (docs/cache_backends.md):
        # admission hashes the bucketed prompt row into a prefix chain,
        # maps already-resident pages by refcount bump, and — when EVERY
        # prompt page is shared — replays the cached prefill outputs
        # instead of recomputing the prompt (zero prefill FLOPs).
        self.prefix_sharing = bool(prefix_sharing)
        if self.prefix_sharing and not getattr(self.backend,
                                               "prefix_sharing", False):
            raise ValueError(
                "prefix_sharing=True needs a PagedBackend built with "
                "prefix_sharing enabled (cache_backend='paged', or pass "
                "a PagedBackend(prefix_sharing=True) instance)")
        # LRU of full-prompt prefill outputs keyed by the chain's last
        # digest: (last-token logits, DRS scores or None).  Bounded so a
        # long-lived engine's host memory stays flat; entries are tiny
        # ((vocab,) logits) next to the KV pool.
        self._prefill_cache: collections.OrderedDict = \
            collections.OrderedDict()
        self._prefill_cache_cap = 128
        self.prefill_cache_hits = 0
        self.cache = self.backend.make(cfg, n_slots, max_seq)
        # zero 1-lane dense template reused by every admission (prefill is
        # functional: the template is never mutated, and its zero tail
        # wipes any stale K/V when merged over a retired dense lane)
        self._lane0 = api.make_cache(cfg, 1, max_seq)
        # token each lane feeds to its next decode step (sampled from the
        # lane's latest logits; junk for free lanes, masked at emit time)
        self._next_tok = np.zeros(n_slots, np.int32)

        # sampling is fused into the jitted decode step (one device
        # dispatch per step; the tiny-model regime is dispatch-bound, see
        # bench_serving.py) — with a separate greedy-only variant so the
        # common all-temperature-0 step never pays the full-vocab
        # sort/softmax of nucleus sampling.  Admission is three
        # dispatches (prefill, backend splice, first-token pick); it runs
        # once per request, not per step.
        def _prefill(p, d, toks, lane0):
            logits, lane = api.prefill(p, d, cfg, {"tokens": toks}, lane0)
            return logits[0], lane

        def _first_tok(logits, key, draw, temp, top_p):
            k = jax.random.fold_in(jax.random.fold_in(key, _ADMIT_SALT),
                                   draw)
            return sample_tokens(logits[None], jax.random.split(k, 1),
                                 temp[None], top_p[None])[0]

        # the engine cache handle is donated: the caller always rebinds
        # self.cache to the result, and donation lets XLA update one
        # lane / one token column in place instead of copying the whole
        # cache every call.  live_pages is static: the paged decode jit
        # compiles one variant per live-page bucket (see _live_pages).
        _decode_greedy, _decode_sample = make_decode_fns(cfg)
        self._jit_prefill = jax.jit(_prefill)
        self._jit_first = jax.jit(_first_tok)
        self._jit_decode_greedy = jax.jit(_decode_greedy,
                                          donate_argnums=(3,),
                                          static_argnums=(7,))
        self._jit_decode_sample = jax.jit(_decode_sample,
                                          donate_argnums=(3,),
                                          static_argnums=(7,))
        # fused decode chunk (ROADMAP: device-resident decode loop) —
        # decode_chunk micro-steps scanned per dispatch; only built when
        # chunking is on, and then the chunk=1 decode jits above are
        # never dispatched (warm_decode warms whichever set is live)
        if decode_chunk > 1:
            _cg, _cs = make_chunked_decode_fns(cfg, decode_chunk, max_seq)
            self._jit_chunk_greedy = jax.jit(_cg, donate_argnums=(3,),
                                             static_argnums=(8,))
            self._jit_chunk_sample = jax.jit(_cs, donate_argnums=(3,),
                                             static_argnums=(8,))

        # DSG serving runtime (serving/dsg_runtime.py): per-lane group-CSR
        # patterns feed a sparse FFN decode; refresh scores ride back out
        # of the refresh-variant decode step
        scfg = dsg_runtime.as_serving_config(dsg_serving)
        self.dsg_rt = None
        if scfg is not None:
            if dsg is None or not cfg.dsg.enabled:
                raise ValueError(
                    "dsg_serving needs DSG state: cfg.dsg.enabled and a "
                    "non-None dsg pytree")
            if cfg.is_moe or cfg.act != "swiglu":
                raise ValueError(
                    "dsg_serving targets the dense SwiGLU FFN family "
                    f"(got act={cfg.act!r}, moe_experts={cfg.moe_experts})")
            if cfg.dsg.score != "relu_sum":
                raise ValueError(
                    "the on-device refresh (kernels/drs_search.drs_scores) "
                    f"computes relu_sum scores; cfg.dsg.score is "
                    f"{cfg.dsg.score!r}")
            if decode_chunk > 1 and scfg.refresh_interval % decode_chunk:
                raise ValueError(
                    f"decode_chunk ({decode_chunk}) must divide the DSG "
                    f"refresh_interval ({scfg.refresh_interval}): refresh "
                    "cadence is per-lane emitted-token count, and a due "
                    "point landing mid-chunk could not rewrite the CSR "
                    "pattern the chunk already dispatched with")
            self.dsg_rt = dsg_runtime.DSGRuntime(cfg, scfg, n_slots)

            def _prefill_dsg(p, d, toks, lane0):
                logits, lane, scores = api.prefill(
                    p, d, cfg, {"tokens": toks}, lane0,
                    collect_drs_scores=True)
                return logits[0], lane, scores

            _dsg_greedy, _dsg_sample = make_dsg_decode_fns(cfg)
            self._jit_prefill_dsg = jax.jit(_prefill_dsg)
            self._jit_decode_greedy_dsg = jax.jit(_dsg_greedy,
                                                  donate_argnums=(3,),
                                                  static_argnums=(7, 9))
            self._jit_decode_sample_dsg = jax.jit(_dsg_sample,
                                                  donate_argnums=(3,),
                                                  static_argnums=(7, 13))
            if decode_chunk > 1:
                _dcg, _dcs = make_chunked_dsg_decode_fns(
                    cfg, decode_chunk, max_seq)
                self._jit_chunk_greedy_dsg = jax.jit(
                    _dcg, donate_argnums=(3,), static_argnums=(8, 10))
                self._jit_chunk_sample_dsg = jax.jit(
                    _dcs, donate_argnums=(3,), static_argnums=(8, 14))

    # -- public API ---------------------------------------------------------

    @exempt("queue", reason="cross-thread entry point: the dispatching "
            "executor serializes it (ThreadedExecutor.dispatch holds "
            "_cond) or no drive is in flight; deque.append is atomic "
            "under the GIL and the REPRO_TSAN guarded deque still "
            "covers the site")
    def submit(self, req: Request):
        # keep an earlier stamp if one exists: a front-end router stamps
        # submission time at ITS queue, and latency should span the whole
        # wait, not just the slice after dispatch to this replica
        req.submitted = req.submitted or time.perf_counter()
        self.queue.append(req)

    @runs_on("worker")
    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        while (self.queue or any(not s.free for s in self.slots)) \
                and self.steps < max_steps:
            self.step()
        return self.done

    def drain(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Run until every queued request is admitted, decoded, and
        retired (no new submissions assumed) — the retirement-draining
        primitive a front-end router calls per replica."""
        return self.run(max_steps=max_steps)

    # -- introspection (read by serving/router.py routing policies) ----------

    def queue_depth(self) -> int:
        """Requests accepted by submit() but not yet admitted to a lane."""
        return len(self.queue)

    def free_slots(self) -> int:
        """Decode lanes currently without a resident request."""
        return sum(s.free for s in self.slots)

    def busy_slots(self) -> int:
        return self.n_slots - self.free_slots()

    def free_pages(self) -> int:
        """Unreserved free pages in the paged backend's BlockAllocator —
        the headroom a router's `least_pages` policy balances on.  Dense
        engines have no allocator; each free lane permanently owns a
        max_seq stripe, reported in equivalent pages of this engine's
        `page_size` so the number stays comparable across backends."""
        if self.cache.kind == "paged":
            return (self.backend.allocator.free_pages
                    - int(self.backend._resv.sum()))
        return self.free_slots() * (self.max_seq // max(self.page_size, 1))

    def _admit_chain(self, req: Request):
        """(prefix chain, prompt bucket) admission would use for `req` —
        None chain when sharing is off.  Exposed to the sharing-aware
        page math below so routing reservations (Router least_pages)
        see the same expected-sharing credit admission will take."""
        pb = self._bucket_for(len(req.prompt))
        if not self.prefix_sharing:
            return None, pb
        toks = np.zeros(pb, np.int32)
        pr = req.prompt[-pb:]
        toks[pb - len(pr):] = pr
        return kv_cache.prefix_chain(toks, self.page_size), pb

    def pages_needed(self, req: Request) -> int:
        """Worst-case page reservation admitting `req` would take (the
        same `min(bucket + max_new, max_seq)` extent _admit reserves).
        With prefix sharing the count credits prompt pages already
        resident (they are mapped, not allocated) and charges the
        partial-tail COW page — so `least_pages` reservations account
        for expected sharing."""
        chain, pb = self._admit_chain(req)
        need = min(pb + req.max_new, self.max_seq)
        if self.cache.kind == "paged":
            pages = self.backend.pages_for(need)
            if chain is not None:
                pages += self.backend.sharing_adjustment(chain, pb)
            return max(pages, 0)
        return -(-need // max(self.page_size, 1))

    def can_admit_request(self, req: Request) -> bool:
        """True when `req`, submitted now with an empty queue ahead of it,
        would be admitted by the next step: a lane is free and the cache
        backend can cover its worst-case reservation (sharing-aware —
        see pages_needed)."""
        chain, pb = self._admit_chain(req)
        need = min(pb + req.max_new, self.max_seq)
        if chain is not None:
            return (self.free_slots() > 0
                    and self.backend.can_admit(need, chain=chain,
                                               prompt_tokens=pb))
        return self.free_slots() > 0 and self.backend.can_admit(need)

    # -- engine internals ---------------------------------------------------

    def _bucket_for(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return self.buckets[-1]      # longer prompts truncate to max bucket

    @runs_on("worker")
    def _remember_prefill(self, key: bytes, logits, sc_np) -> None:
        """Cache a full-prompt prefill result (last-token logits + DRS
        scores) under the prompt chain's final digest, LRU-bounded.  The
        entry is only ever REPLAYED when every prompt page is still
        resident, and it reproduces the prefill bitwise: identical
        padded tokens through the same jitted prefill yield identical
        logits, so the first sampled/greedy token — and with it the
        whole stream — matches the recompute path exactly."""
        self._prefill_cache[key] = (logits, sc_np)
        self._prefill_cache.move_to_end(key)
        while len(self._prefill_cache) > self._prefill_cache_cap:
            self._prefill_cache.popitem(last=False)

    @runs_on("worker")
    def _admit(self):
        """Admit queued prompts into free lanes via backend cache surgery.

        Overlap policy: every free lane refills immediately (subject to
        the paged backend having pages for the request's reservation).
        Wave policy: admission waits until ALL lanes have drained (the old
        baseline)."""
        if self.admission == "wave" and any(not s.free for s in self.slots):
            return
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            # deadline enforcement at the admission boundary: a request
            # whose deadline lapsed while queued retires as timed_out
            # instead of occupying a lane (the router also expires its
            # own queue — this covers push policies that dispatch
            # eagerly, and bare engines; see docs/fault_tolerance.md)
            while self.queue:
                req = self.queue[0]
                if (req.deadline_s is None
                        or time.perf_counter() - req.submitted
                        <= req.deadline_s):
                    break
                self.queue.popleft()
                req.status = "timed_out"
                req.finished = time.perf_counter()
                self.done[req.uid] = req
            if not self.queue:
                break
            plen = len(req.prompt)
            pb = self._bucket_for(plen)
            if plen > pb:
                req.truncated = True
                if not self._warned_truncation:
                    warnings.warn(
                        f"prompt of request {req.uid} ({plen} tokens) "
                        f"exceeds the largest bucket ({pb}); keeping the "
                        f"last {pb} tokens (warned once per engine)")
                    self._warned_truncation = True
            need = min(pb + req.max_new, self.max_seq)
            toks = np.zeros((1, pb), np.int32)
            pr = req.prompt[-pb:]
            toks[0, pb - len(pr):] = pr
            # prefix sharing: the chain keys the BUCKETED row (padding
            # included) — page bytes are a pure function of the padded
            # prefix, so only identical padded prefixes may alias
            chain = (kv_cache.prefix_chain(toks[0], self.page_size)
                     if self.prefix_sharing else None)
            admit_ok = (self.backend.can_admit(need, chain=chain,
                                               prompt_tokens=pb)
                        if chain is not None
                        else self.backend.can_admit(need))
            if not admit_ok:
                break            # retirements will free pages; retry later
            self.queue.popleft()
            # zero-recompute path: every prompt page resident AND the
            # full-prompt prefill outputs cached -> skip the prefill
            # dispatch and the K/V scatter entirely.  Probe and write
            # run back to back on this worker thread, so a hit cannot
            # go stale in between.
            cached = None
            if chain is not None \
                    and self.backend.shared_hits(chain) == len(chain):
                cached = self._prefill_cache.get(chain[-1])
            if cached is not None:
                self._prefill_cache.move_to_end(chain[-1])
                self.prefill_cache_hits += 1
                logits, sc_np = cached
                lane = None
                if self.dsg_rt is not None:
                    self.dsg_rt.set_lane_from_scores(i, sc_np[:, 0])
            elif self.dsg_rt is not None:
                logits, lane, sc = self._jit_prefill_dsg(
                    self.params, self.dsg, jnp.asarray(toks), self._lane0)
                # seed the lane's CSR pattern from the prompt's last-token
                # DRS scores: the lane decodes sparsely from step one (a
                # dense warm-in would dilute the modeled FLOP reduction)
                sc_np = np.asarray(sc)
                self.dsg_rt.set_lane_from_scores(i, sc_np[:, 0])
                if chain is not None:
                    self._remember_prefill(chain[-1], logits, sc_np)
            else:
                logits, lane = self._jit_prefill(self.params, self.dsg,
                                                 jnp.asarray(toks),
                                                 self._lane0)
                if chain is not None:
                    self._remember_prefill(chain[-1], logits, None)
            self.cache = self.backend.write(self.cache, lane, i,
                                            n_tokens=pb, reserve_tokens=need,
                                            chain=chain)
            # _draws advances for every admission so the sampling key
            # schedule doesn't depend on how many greedy requests preceded
            self._draws += 1
            if req.temperature > 0:
                tok = self._jit_first(logits, self._base_key, self._draws,
                                      np.float32(req.temperature),
                                      np.float32(req.top_p))
            else:
                tok = jnp.argmax(logits)
            req.started = time.perf_counter()
            slot.req = req
            slot.pos = pb
            self._next_tok[i] = int(tok)

    def _live_pages(self, pos: np.ndarray, span: int = 1) -> int:
        """Static page-walk bound for this step's paged decode
        (live_page_bound over the DEEPEST lane; free lanes mirror an
        active donor, so the active max covers them).  The attention
        executor reads only these pages — the whole point of the paged
        layout (ROADMAP: read only live pages).  `span` widens the bound
        to cover a fused chunk's deepest write (pos + span - 1); reads
        past a lane's depth are masked, so a wider bound only changes
        which pow2 compile variant runs, never the gathered values."""
        if self.cache.kind != "paged":
            return 0
        deepest = min(int(pos.max()) + span - 1, self.max_seq - 1)
        return live_page_bound(deepest, self.cache.page_size,
                               self.max_seq // self.cache.page_size)

    @runs_on("worker")
    def warm_decode(self, sample: bool = False):
        """Pre-compile the jitted decode step for every static live-page
        bucket this engine can reach (_live_pages yields the pow2 series
        up to max_pages; dense engines have a single variant), so no
        compile lands inside a measured decode window.  Dispatches real
        decode steps against the idle cache: every lane mirrors donor 0
        and scatters into the scratch page (paged) or into lane bytes the
        next admission fully overwrites (dense) — no later gather
        observes the writes."""
        if self.cache.kind == "paged":
            buckets = live_page_buckets(self.max_seq // self.cache.page_size)
        else:
            buckets = [0]
        tok = jnp.zeros((self.n_slots, 1), jnp.int32)
        pos = jnp.zeros(self.n_slots, jnp.int32)
        free_mask = np.ones(self.n_slots, np.bool_)
        temps = np.full(self.n_slots, 0.5, np.float32)
        top_ps = np.ones(self.n_slots, np.float32)
        if self.decode_chunk > 1:
            # a chunked engine only ever dispatches the fused variants —
            # warm those instead.  All-done lanes mirror lane 0 exactly
            # like the chunk=1 warm (writes land in the scratch page /
            # overwritten lane bytes), and emit nothing.
            tok1 = jnp.zeros(self.n_slots, jnp.int32)
            done = jnp.ones(self.n_slots, bool)
            left = jnp.ones(self.n_slots, jnp.int32)
            eos = jnp.full(self.n_slots, -1, jnp.int32)
            for live in buckets:
                if self.dsg_rt is not None:
                    for bnd in self.dsg_rt.warm_bounds():
                        csr = self.dsg_rt.device_csr(bnd)
                        for refresh in (False, True):
                            _, _, _, self.cache, _ = \
                                self._jit_chunk_greedy_dsg(
                                    self.params, self.dsg, tok1,
                                    self.cache, pos, done, left, eos,
                                    live, csr, refresh)
                            if sample:
                                _, _, _, self.cache, _ = \
                                    self._jit_chunk_sample_dsg(
                                        self.params, self.dsg, tok1,
                                        self.cache, pos, done, left, eos,
                                        live, csr, self._base_key, 0,
                                        temps, top_ps, refresh)
                    continue
                _, _, _, self.cache = self._jit_chunk_greedy(
                    self.params, self.dsg, tok1, self.cache, pos, done,
                    left, eos, live)
                if sample:
                    _, _, _, self.cache = self._jit_chunk_sample(
                        self.params, self.dsg, tok1, self.cache, pos,
                        done, left, eos, live, self._base_key, 0, temps,
                        top_ps)
            return
        for live in buckets:
            if self.dsg_rt is not None:
                # (bound x refresh) variants of the DSG decode step; the
                # plain decode fns are never dispatched by a DSG engine
                for bnd in self.dsg_rt.warm_bounds():
                    csr = self.dsg_rt.device_csr(bnd)
                    for refresh in (False, True):
                        _, self.cache, _ = self._jit_decode_greedy_dsg(
                            self.params, self.dsg, tok, self.cache, pos,
                            free_mask, 0, live, csr, refresh)
                        if sample:
                            _, self.cache, _ = self._jit_decode_sample_dsg(
                                self.params, self.dsg, tok, self.cache,
                                pos, free_mask, 0, live, csr,
                                self._base_key, 0, temps, top_ps, refresh)
                continue
            _, self.cache = self._jit_decode_greedy(
                self.params, self.dsg, tok, self.cache, pos, free_mask, 0,
                live)
            if sample:
                _, self.cache = self._jit_decode_sample(
                    self.params, self.dsg, tok, self.cache, pos, free_mask,
                    0, live, self._base_key, 0, temps, top_ps)

    @runs_on("worker")
    def begin_step(self) -> Optional[StepPlan]:
        """Host half of a decode step: admit queued prompts, emit each
        active lane's pending token, grow page tables for this step's
        write positions, and build the decode operands.  Returns None
        when no lane is active (and raises if prompts are queued but can
        never be admitted).  Callers must follow a non-None plan with
        the jitted decode dispatch and `commit_step()` — `step()` is
        that composition; replica executors batch the middle."""
        if self.abort:
            # cleared here (not left sticky) so a restarted replica does
            # not immediately re-abort; ServingEngine.reset() also clears
            self.abort = False
            raise EngineAborted(
                f"replica {self.replica_index} aborted at step boundary "
                f"(stall-timeout containment)")
        if self.fault_injector is not None:
            # chaos harness hook: kill raises here (before this step's
            # tokens land), delay sleeps inside the step, poison corrupts
            # resident outputs then raises — see runtime/fault_tolerance
            self.fault_injector.on_step(self)
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            if self.queue:
                raise RuntimeError(
                    "engine stalled: queued prompts cannot be admitted — "
                    "the paged cache pool is smaller than a single "
                    "request's page reservation; raise cache_tokens or "
                    "lower max_new/prompt_bucket")
            return None
        # Free/retired lanes mirror the first active lane instead of feeding
        # an arbitrary pad token: with the paper's inter-sample threshold
        # sharing (DRS threshold_mode="shared", taken from batch row 0) an
        # idle lane 0 would otherwise drive every live lane's sparsity mask
        # with junk.  Mirrored lanes emit nothing; their K/V scribbles land
        # in a lane that the next admission fully overwrites (dense) or in
        # the donor's own pages as identical duplicates (paged — see
        # kv_cache.decode_view) and are never observed.
        donor = active[0]
        tok = np.array(self._next_tok, np.int32)
        pos = np.empty(self.n_slots, np.int32)
        free_mask = np.zeros(self.n_slots, np.bool_)
        temps = np.zeros(self.n_slots, np.float32)
        top_ps = np.ones(self.n_slots, np.float32)
        C = self.decode_chunk
        eos_ids = np.full(self.n_slots, -1, np.int32)
        emit_left = np.ones(self.n_slots, np.int32)
        for i, s in enumerate(self.slots):
            if s.free:
                free_mask[i] = True
                tok[i] = self._next_tok[donor]
                pos[i] = self.slots[donor].pos
            elif C == 1:
                pos[i] = s.pos
                temps[i] = s.req.temperature
                top_ps[i] = s.req.top_p
                # page-table growth for this step's write position (no-op
                # for the dense backend or when the page is already mapped)
                self.cache = self.backend.ensure(self.cache, i, s.pos)
            else:
                pos[i] = s.pos
                temps[i] = s.req.temperature
                top_ps[i] = s.req.top_p
                r = s.req
                eos_ids[i] = -1 if r.eos_id is None else r.eos_id
                emit_left[i] = r.max_new - len(r.output)
                # the fused chunk cannot grow the page table mid-scan, so
                # `ensure` moves ahead of the loop: pre-map every page the
                # lane can write this chunk.  Clamping to the lane's own
                # emit budget / max_seq headroom keeps the mapping inside
                # its admission-time reservation (ensure stays infallible)
                w = min(C, int(emit_left[i]), self.max_seq - s.pos)
                self.cache = self.backend.ensure_range(self.cache, i,
                                                       s.pos, s.pos + w)
        if C == 1:
            for i in active:
                r = self.slots[i].req
                if not r.output:
                    r.first_token = time.perf_counter()   # TTFT stamp
                r.output.append(int(tok[i]))
            return StepPlan(active=active, donor=donor, tok=tok, pos=pos,
                            free_mask=free_mask, temps=temps, top_ps=top_ps,
                            live_pages=self._live_pages(pos),
                            sample=bool((temps > 0).any()))
        # chunked: emission happens on device; commit_chunk appends.  A
        # DSG refresh-due point can only land on the last micro-step
        # (refresh_interval % chunk == 0 and lanes admit at chunk
        # boundaries) — predict it here so the dispatch picks the
        # score-collecting compile variant.  Lanes that would freeze on
        # budget/max_seq before the last micro-step never reach their due
        # token; an unpredicted EOS freeze just wastes one score read.
        refresh = False
        if self.dsg_rt is not None:
            R = self.dsg_rt.cfg.refresh_interval
            refresh = any(
                (len(self.slots[i].req.output) + C) % R == 0
                and int(emit_left[i]) >= C
                and self.max_seq - self.slots[i].pos >= C
                for i in active)
        return StepPlan(active=active, donor=donor, tok=tok, pos=pos,
                        free_mask=free_mask, temps=temps, top_ps=top_ps,
                        live_pages=self._live_pages(pos, C),
                        sample=bool((temps > 0).any()), chunk=C,
                        eos_ids=eos_ids, emit_left=emit_left,
                        refresh=refresh)

    @runs_on("worker")
    def commit_step(self, plan: StepPlan, next_tok: np.ndarray,
                    seconds: float):
        """Record a decode result: latch each lane's next input token,
        account the device time/tokens, and retire finished lanes.
        `next_tok` must already be host-side (the caller syncs — that is
        where the device wait belongs in the timing)."""
        self._next_tok = np.array(next_tok, np.int32)
        self.decode_seconds += seconds
        self.decode_tokens += len(plan.active)
        self.steps += 1
        # per-slot retirement — AFTER the EOS token has been emitted, so a
        # stop token always appears in the output it terminates
        for i in plan.active:
            slot = self.slots[i]
            slot.pos += 1
            r = slot.req
            hit_eos = r.eos_id is not None and r.output[-1] == r.eos_id
            if hit_eos or len(r.output) >= r.max_new \
                    or slot.pos >= self.max_seq:
                r.status = "ok"
                r.finished = time.perf_counter()
                self.done[r.uid] = r
                slot.req = None
                slot.pos = 0
                self.cache = self.backend.free(self.cache, i)

    @runs_on("worker")
    def commit_chunk(self, plan: StepPlan, blk: np.ndarray,
                     flags: np.ndarray, next_tok: np.ndarray,
                     seconds: float, *, scores=None, bound=None):
        """Record a fused decode chunk: append each lane's emitted tokens
        (a lane's flag column is a monotone prefix — once frozen it emits
        nothing more), latch pending next-step tokens, advance `steps` by
        the micro-steps that had a live lane, and retire finished lanes.
        Host bookkeeping lags a full chunk behind the device; retirement
        re-derives the freeze conditions from the appended output, which
        mirrors the device's done logic exactly (EOS == output[-1],
        len(output) >= max_new, pos >= max_seq)."""
        rt = self.dsg_rt
        if rt is not None and bound is not None:
            # one FLOP-model entry per micro-step, over the lanes still
            # live at that micro-step — keeps flop_stats comparable to a
            # chunk=1 run of the same traffic
            for k in range(flags.shape[0]):
                live = [i for i in plan.active if flags[k, i]]
                if live:
                    rt.record_step(live, bound)
        emitted = 0
        for i in plan.active:
            slot = self.slots[i]
            n = int(flags[:, i].sum())
            if n and not slot.req.output:
                # TTFT stamp at host observation time: the token left the
                # device mid-chunk, but commit is when a caller could
                # first stream it — the honest latency for a fused loop
                slot.req.first_token = time.perf_counter()
            slot.req.output.extend(int(t) for t in blk[:n, i])
            slot.pos += n
            emitted += n
        self._next_tok = np.array(next_tok, np.int32)
        self.decode_seconds += seconds
        self.decode_tokens += emitted
        self.steps += int(flags.any(axis=1).sum())
        retired = []
        for i in plan.active:
            slot = self.slots[i]
            r = slot.req
            hit_eos = r.eos_id is not None and r.output[-1] == r.eos_id
            if hit_eos or len(r.output) >= r.max_new \
                    or slot.pos >= self.max_seq:
                r.status = "ok"
                r.finished = time.perf_counter()
                self.done[r.uid] = r
                slot.req = None
                slot.pos = 0
                self.cache = self.backend.free(self.cache, i)
                retired.append(i)
        if rt is not None:
            for i in retired:
                rt.reset_lane(i)
            if scores is not None:
                R = rt.cfg.refresh_interval
                due = [i for i in plan.active
                       if self.slots[i].req is not None
                       and len(self.slots[i].req.output) % R == 0]
                rt.update_from_scores(np.asarray(scores), due)

    @runs_on("worker")
    def _dispatch_chunk(self, plan: StepPlan):
        """Device half of a fused chunk: one jitted dispatch running
        `plan.chunk` scanned decode micro-steps.  Returns host-side
        (blk, flags, next_tok) plus (scores, bound) for DSG engines."""
        args = (self.params, self.dsg, jnp.asarray(plan.tok), self.cache,
                jnp.asarray(plan.pos), jnp.asarray(plan.free_mask),
                jnp.asarray(plan.emit_left), jnp.asarray(plan.eos_ids),
                plan.live_pages)
        scores = bound = None
        if self.dsg_rt is not None:
            rt = self.dsg_rt
            bound = rt.bound()
            csr = rt.device_csr(bound)
            if plan.sample:
                blk, flags, tok_f, self.cache, scores = \
                    self._jit_chunk_sample_dsg(
                        *args, csr, self._base_key, self.steps,
                        plan.temps, plan.top_ps, plan.refresh)
            else:
                blk, flags, tok_f, self.cache, scores = \
                    self._jit_chunk_greedy_dsg(*args, csr, plan.refresh)
        elif plan.sample:
            blk, flags, tok_f, self.cache = self._jit_chunk_sample(
                *args, self._base_key, self.steps, plan.temps,
                plan.top_ps)
        else:
            blk, flags, tok_f, self.cache = self._jit_chunk_greedy(*args)
        return (np.asarray(blk), np.asarray(flags),
                np.array(tok_f, np.int32), scores, bound)

    @runs_on("worker")
    def _dispatch_dsg(self, plan: StepPlan):
        """DSG-serving decode dispatch: per-lane refresh cadence (a lane
        is due when its emitted-token count crosses refresh_interval —
        depending only on the lane's own history, so streams stay
        invariant to co-scheduling and replica count), CSR operands at
        the current pow2 bound, and the FLOP-model log entry."""
        rt = self.dsg_rt
        due = [i for i in plan.active
               if len(self.slots[i].req.output)
               % rt.cfg.refresh_interval == 0]
        refresh = bool(due)
        bound = rt.bound()
        csr = rt.device_csr(bound)
        rt.record_step(plan.active, bound)
        if plan.sample:
            next_tok, self.cache, scores = self._jit_decode_sample_dsg(
                self.params, self.dsg, jnp.asarray(plan.tok)[:, None],
                self.cache, jnp.asarray(plan.pos), plan.free_mask,
                plan.donor, plan.live_pages, csr, self._base_key,
                self.steps, plan.temps, plan.top_ps, refresh)
        else:
            next_tok, self.cache, scores = self._jit_decode_greedy_dsg(
                self.params, self.dsg, jnp.asarray(plan.tok)[:, None],
                self.cache, jnp.asarray(plan.pos), plan.free_mask,
                plan.donor, plan.live_pages, csr, refresh)
        return next_tok, scores, due

    @runs_on("worker")
    def step(self):
        """One full engine step: begin (host) -> jitted decode (device)
        -> commit (host).  Replica executors that batch the device half
        across engines call the begin/commit halves directly."""
        plan = self.begin_step()
        if plan is None:
            return
        if plan.chunk > 1:
            t0 = time.perf_counter()
            blk, flags, tok_f, scores, bound = self._dispatch_chunk(plan)
            self.commit_chunk(plan, blk, flags, tok_f,
                              time.perf_counter() - t0, scores=scores,
                              bound=bound)
            return
        t0 = time.perf_counter()
        scores = due = None
        # PRNG keys depend only on (engine seed, step, lane), so mixing
        # greedy-only and sampling steps never shifts the key schedule
        if self.dsg_rt is not None:
            next_tok, scores, due = self._dispatch_dsg(plan)
        elif plan.sample:
            next_tok, self.cache = self._jit_decode_sample(
                self.params, self.dsg, jnp.asarray(plan.tok)[:, None],
                self.cache, jnp.asarray(plan.pos), plan.free_mask,
                plan.donor, plan.live_pages, self._base_key, self.steps,
                plan.temps, plan.top_ps)
        else:
            next_tok, self.cache = self._jit_decode_greedy(
                self.params, self.dsg, jnp.asarray(plan.tok)[:, None],
                self.cache, jnp.asarray(plan.pos), plan.free_mask,
                plan.donor, plan.live_pages)
        next_host = np.array(next_tok, np.int32)       # syncs the device
        self.commit_step(plan, next_host, time.perf_counter() - t0)
        if self.dsg_rt is not None:
            # host pattern bookkeeping lags the device step (the paged
            # page-table split): retire first, then rewrite due lanes
            # from the refresh scores (update skips inactive lanes)
            for i in plan.active:
                if self.slots[i].req is None:          # retired in commit
                    self.dsg_rt.reset_lane(i)
            if scores is not None:
                self.dsg_rt.update_from_scores(np.asarray(scores), due)

    # -- fault containment (called by serving/router.py failover) ------------
    #
    # These run under the "worker" role like every other engine mutation.
    # During failover the replica's own worker is gone (it raised and
    # exited, or never existed under the lockstep executors), so the
    # router thread is momentarily the engine's driver — the threaded
    # executor serializes that handoff under its condition lock and, with
    # REPRO_TSAN=1, re-resolves the role to quiescent before the router
    # touches the engine.

    @runs_on("worker")
    def evict_slot(self, i: int) -> Optional[Request]:
        """Release lane `i` mid-flight and return its request (None when
        the lane is free): the lane's pages return to the backend pool
        and its DSG pattern resets, exactly as retirement would, but the
        request does NOT land in `done`.  The partial output is kept —
        the caller decides between replay (the router's failover clears
        it so re-decode from the prompt is bit-identical at temperature
        0) and surfacing the partial stream."""
        slot = self.slots[i]
        req = slot.req
        if req is None:
            return None
        slot.req = None
        slot.pos = 0
        self.cache = self.backend.free(self.cache, i)
        if self.dsg_rt is not None:
            self.dsg_rt.reset_lane(i)
        return req

    @runs_on("worker")
    def evict_request(self, uid: int) -> Optional[Request]:
        """Evict request `uid` wherever it sits — a resident lane (freed
        via evict_slot) or the admission queue.  Returns the request, or
        None when it is not on this engine (already retired or never
        dispatched here)."""
        for i, slot in enumerate(self.slots):
            if slot.req is not None and slot.req.uid == uid:
                return self.evict_slot(i)
        for req in self.queue:
            if req.uid == uid:
                self.queue.remove(req)
                return req
        return None

    @runs_on("worker")
    def reset(self) -> List[Request]:
        """Reclaim every queued + resident request and return them in
        admission order (resident lanes by slot index — they were
        admitted first — then the queue FIFO).  `done` is preserved:
        requests that retired before the failure completed correctly.
        The engine itself stays warm (compiled callables, cache pool,
        PRNG base key) — this IS the replica restart path; a restarted
        replica serves its next request with no recompilation."""
        reclaimed = []
        for i in range(self.n_slots):
            req = self.evict_slot(i)
            if req is not None:
                reclaimed.append(req)
        reclaimed.extend(self.queue)
        self.queue.clear()
        self._next_tok[:] = 0
        self.abort = False
        return reclaimed

    # -- stats ---------------------------------------------------------------

    def throughput(self) -> float:
        """End-to-end tok/s over the span from first ADMISSION to last
        finish.  (An earlier version divided by the submit->finish span,
        which charges the engine for queue wait accrued before it ever
        ran — e.g. requests submitted long before run().)

        Raises ValueError before any request has finished: there is no
        admission->finish window yet, and the old 0.0 return read as "the
        engine is infinitely slow" in benchmark ratios."""
        if not self.done:
            raise ValueError(
                "throughput() needs at least one finished request; "
                "run the engine (or drain()) before reading stats")
        toks = sum(len(r.output) for r in self.done.values())
        t0 = min(r.started or r.submitted for r in self.done.values())
        t1 = max(r.finished for r in self.done.values())
        return toks / max(t1 - t0, 1e-9)

    def decode_tok_per_s(self) -> float:
        """Decode-only rate: emitted tokens over time spent inside the
        jitted decode step (excludes admission/prefill and host
        scheduling), the number to watch for cache-backend regressions.

        Raises ValueError before any decode step has emitted a token
        (same contract as throughput())."""
        if not self.decode_tokens:
            raise ValueError(
                "decode_tok_per_s() needs at least one decoded token; "
                "run the engine before reading stats")
        return self.decode_tokens / max(self.decode_seconds, 1e-9)

    def latencies(self) -> np.ndarray:
        """Per-request completion latency (submit -> finish) in seconds."""
        return np.array(sorted(r.finished - r.submitted
                               for r in self.done.values()))
