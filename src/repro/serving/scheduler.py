"""Continuous-batching serving scheduler.

Fixed-slot continuous batching (vLLM-style, static shapes for XLA): the
engine keeps `n_slots` decode lanes; finished/empty lanes are refilled
from the request queue each step, the decode step always runs the full
(padded) batch, and per-slot position counters + EOS/length checks retire
sequences.  Prefill is per-admission (one jit'd prefill per prompt shape
bucket); the KV cache is written in-place per slot via the batched cache.

This is the single-host engine; at pod scale the same slot logic runs
per data-parallel replica group with the model sharded over 'model'
(the decode step is already the dry-run-verified sharded function).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    submitted: float = 0.0
    finished: float = 0.0


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                      # next write position in the cache

    @property
    def free(self) -> bool:
        return self.req is None


class ServingEngine:
    """Continuous batching over a fixed slot count.

    Static-shape discipline: prompts are right-aligned into a fixed
    `prompt_bucket` window (shorter prompts left-padded and positions
    offset), so there is exactly ONE prefill computation and ONE decode
    computation to compile.
    """

    def __init__(self, cfg, params, dsg, *, n_slots: int = 4,
                 max_seq: int = 256, prompt_bucket: int = 64):
        self.cfg = cfg
        self.params = params
        self.dsg = dsg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prompt_bucket = min(prompt_bucket, max_seq)
        self.queue: collections.deque = collections.deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.done: Dict[int, Request] = {}
        self.steps = 0

        self.cache = api.make_cache(cfg, n_slots, max_seq)
        self._state = None            # engine-wide decode state

        self._jit_decode = jax.jit(
            lambda p, d, tok, st, pos: api.decode_step(p, d, cfg, tok, st,
                                                       pos))
        self._jit_prefill = jax.jit(
            lambda p, d, inp, c: api.prefill(p, d, cfg, inp, c))

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request):
        req.submitted = time.time()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        while (self.queue or any(not s.free for s in self.slots)) \
                and self.steps < max_steps:
            self.step()
        return self.done

    # -- engine internals -----------------------------------------------------

    def _admit(self):
        """Fill free slots from the queue (batched prefill for the new
        admissions).  Prompts are truncated/left-padded to prompt_bucket."""
        new = []
        for i, slot in enumerate(self.slots):
            if slot.free and self.queue:
                slot.req = self.queue.popleft()
                slot.pos = 0
                new.append(i)
        if not new:
            return
        pb = self.prompt_bucket
        toks = np.zeros((self.n_slots, pb), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.req is not None and slot.pos == 0:
                pr = slot.req.prompt[-pb:]
                toks[i, pb - len(pr):] = pr
        logits, state = self._jit_prefill(self.params, self.dsg,
                                          {"tokens": jnp.asarray(toks)},
                                          self.cache)
        # engine state is shared across slots (batched cache); admissions
        # reset everyone's cache content, so we only admit in waves when
        # ALL slots are free or at t=0.  (Fixed-wave variant; per-slot
        # cache surgery is the TODO for overlap-admission.)
        self._state = state
        self._last_logits = logits
        for slot in self.slots:
            if slot.req is not None:
                slot.pos = pb

    def step(self):
        # wave admission: only when no active slot holds a sequence
        if all(s.free or s.pos == 0 for s in self.slots):
            self._admit()
        if self._state is None:
            return
        # sample greedily per slot, decode one step for the whole batch
        tok = np.asarray(jnp.argmax(self._last_logits, -1), np.int32)
        pos = max(s.pos for s in self.slots if not s.free)
        for i, slot in enumerate(self.slots):
            if not slot.free:
                slot.req.output.append(int(tok[i]))
        logits, self._state = self._jit_decode(
            self.params, self.dsg, jnp.asarray(tok)[:, None],
            self._state, jnp.int32(pos))
        self._last_logits = logits
        self.steps += 1
        # retire finished sequences
        for slot in self.slots:
            if slot.free:
                continue
            slot.pos = pos + 1
            r = slot.req
            hit_eos = r.eos_id is not None and r.output \
                and r.output[-1] == r.eos_id
            if len(r.output) >= r.max_new or hit_eos \
                    or slot.pos >= self.max_seq:
                r.finished = time.time()
                self.done[r.uid] = r
                slot.req = None
                slot.pos = 0

    # -- stats ---------------------------------------------------------------

    def throughput(self) -> float:
        toks = sum(len(r.output) for r in self.done.values())
        if not self.done:
            return 0.0
        t0 = min(r.submitted for r in self.done.values())
        t1 = max(r.finished for r in self.done.values())
        return toks / max(t1 - t0, 1e-9)
