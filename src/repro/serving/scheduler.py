"""Continuous-batching serving scheduler with overlap admission.

Fixed-slot continuous batching (vLLM-style, static shapes for XLA): the
engine keeps `n_slots` decode lanes and admits a new prompt into ANY free
lane on ANY step — per-slot KV-cache surgery (api.prefill_slot +
api.merge_slot_cache) prefills the prompt against a throwaway 1-lane cache
and scatters its K/V pages into the freed lane while the other lanes keep
decoding.  Per-slot position counters stay honest (the decode step takes a
per-lane position vector), retirement is per-slot on EOS-after-emit /
max_new / max_seq, and retired lanes are masked out of sampling.

Prompt lengths are bucketed (DEFAULT_BUCKETS, capped at `prompt_bucket`)
so admission compiles one prefill per bucket — a small fixed set of
shapes; the decode step compiles exactly once.

`admission="wave"` preserves the old drain-then-refill policy (admit only
when every lane is free) as a benchmark baseline — bench_serving.py
measures the overlap speedup against it on mixed-length traffic.

This is the single-host engine; at pod scale the same slot logic runs
per data-parallel replica group with the model sharded over 'model'
(the decode step is already the dry-run-verified sharded function).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api

DEFAULT_BUCKETS = (16, 32, 64, 96, 128, 192, 256)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    submitted: float = 0.0
    finished: float = 0.0


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                      # next write position in the cache

    @property
    def free(self) -> bool:
        return self.req is None


class ServingEngine:
    """Continuous batching over a fixed slot count.

    Static-shape discipline: a prompt is right-aligned into the smallest
    length bucket that holds it (shorter prompts left-padded), so there is
    one prefill computation per bucket and ONE decode computation to
    compile.  Each admission runs a 1-lane prefill and splices the result
    into the live batched cache — active lanes' K/V bytes are never
    touched, and under per-row DRS selection (threshold_mode="topk")
    their outputs are bit-identical to a solo run (see
    tests/test_serving_overlap.py).  With the paper's inter-sample
    threshold sharing (threshold_mode="shared") all lanes couple to batch
    row 0's scores by design; the engine keeps that row meaningful by
    mirroring idle lanes onto an active one.
    """

    def __init__(self, cfg, params, dsg, *, n_slots: int = 4,
                 max_seq: int = 256, prompt_bucket: int = 64,
                 buckets: Optional[Sequence[int]] = None,
                 admission: str = "overlap"):
        if admission not in ("overlap", "wave"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.cfg = cfg
        self.params = params
        self.dsg = dsg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prompt_bucket = min(prompt_bucket, max_seq)
        bs = buckets if buckets is not None else DEFAULT_BUCKETS
        self.buckets = tuple(sorted({min(b, self.prompt_bucket) for b in bs}))
        self.admission = admission
        self.queue: collections.deque = collections.deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.done: Dict[int, Request] = {}
        self.steps = 0

        self.cache = api.make_cache(cfg, n_slots, max_seq)
        # zero 1-lane template reused by every admission (prefill is
        # functional: the template is never mutated, and its zero tail
        # wipes any stale K/V when merged over a retired lane)
        self._lane0 = api.make_slot_cache(cfg, max_seq)
        # token each lane feeds to its next decode step (argmax of the
        # lane's latest logits; junk for free lanes, masked at emit time)
        self._next_tok = np.zeros(n_slots, np.int32)

        # greedy sampling is fused into the jitted steps so decode and
        # admission are each a single device dispatch (the tiny-model
        # regime is dispatch-bound; see bench_serving.py)
        def _decode(p, d, tok, c, pos):
            logits, c = api.decode_step(p, d, cfg, tok, c, pos)
            return jnp.argmax(logits, -1).astype(jnp.int32), c

        def _admit_one(p, d, toks, lane0, c, slot):
            logits, lane = api.prefill_slot(p, d, cfg, toks, lane0)
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            return tok, api.merge_slot_cache(c, lane, slot)

        # the engine cache is donated: the caller always rebinds
        # self.cache to the result, and donation lets XLA update one
        # lane / one token column in place instead of copying the whole
        # (L, n_slots, Smax, Kv, D) cache every call
        self._jit_decode = jax.jit(_decode, donate_argnums=(3,))
        self._jit_admit = jax.jit(_admit_one, donate_argnums=(4,))

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request):
        req.submitted = time.time()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        while (self.queue or any(not s.free for s in self.slots)) \
                and self.steps < max_steps:
            self.step()
        return self.done

    # -- engine internals ---------------------------------------------------

    def _bucket_for(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return self.buckets[-1]      # longer prompts truncate to max bucket

    def _admit(self):
        """Admit queued prompts into free lanes via per-slot cache surgery.

        Overlap policy: every free lane refills immediately.  Wave policy:
        admission waits until ALL lanes have drained (the old baseline)."""
        if self.admission == "wave" and any(not s.free for s in self.slots):
            return
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue.popleft()
            pb = self._bucket_for(len(req.prompt))
            toks = np.zeros((1, pb), np.int32)
            pr = req.prompt[-pb:]
            toks[0, pb - len(pr):] = pr
            tok, self.cache = self._jit_admit(self.params, self.dsg,
                                              jnp.asarray(toks), self._lane0,
                                              self.cache, i)
            slot.req = req
            slot.pos = pb
            self._next_tok[i] = int(tok)

    def step(self):
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return
        # Free/retired lanes mirror the first active lane instead of feeding
        # an arbitrary pad token: with the paper's inter-sample threshold
        # sharing (DRS threshold_mode="shared", taken from batch row 0) an
        # idle lane 0 would otherwise drive every live lane's sparsity mask
        # with junk.  Mirrored lanes emit nothing and their K/V scribbles
        # are wiped by the full-lane merge on the next admission.
        donor = active[0]
        tok = np.array(self._next_tok, np.int32)
        pos = np.empty(self.n_slots, np.int32)
        for i, s in enumerate(self.slots):
            if s.free:
                tok[i] = self._next_tok[donor]
                pos[i] = self.slots[donor].pos
            else:
                pos[i] = s.pos
        for i in active:
            self.slots[i].req.output.append(int(tok[i]))
        next_tok, self.cache = self._jit_decode(
            self.params, self.dsg, jnp.asarray(tok)[:, None],
            self.cache, jnp.asarray(pos))
        self._next_tok = np.array(next_tok, np.int32)
        self.steps += 1
        # per-slot retirement — AFTER the EOS token has been emitted, so a
        # stop token always appears in the output it terminates
        for i in active:
            slot = self.slots[i]
            slot.pos += 1
            r = slot.req
            hit_eos = r.eos_id is not None and r.output[-1] == r.eos_id
            if hit_eos or len(r.output) >= r.max_new \
                    or slot.pos >= self.max_seq:
                r.finished = time.time()
                self.done[r.uid] = r
                slot.req = None
                slot.pos = 0

    # -- stats ---------------------------------------------------------------

    def throughput(self) -> float:
        toks = sum(len(r.output) for r in self.done.values())
        if not self.done:
            return 0.0
        t0 = min(r.submitted for r in self.done.values())
        t1 = max(r.finished for r in self.done.values())
        return toks / max(t1 - t0, 1e-9)

    def latencies(self) -> np.ndarray:
        """Per-request completion latency (submit -> finish) in seconds."""
        return np.array(sorted(r.finished - r.submitted
                               for r in self.done.values()))
