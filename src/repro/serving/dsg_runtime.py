"""Serving-side DSG runtime: per-lane group-CSR patterns + DRS refresh.

The training stack runs the dimension-reduction search online, per token,
inside the forward (core/dsg_linear.swiglu_dsg_mask) — and then multiplies
a dense mask into a full matmul, saving nothing at serve time.  This
runtime moves the selection OUT of the decode hot path:

  * Each lane (slot) holds a per-layer active-group index list in the
    structured group-CSR form of core/sparse_mask.py, seeded at admission
    from the DRS scores of the prompt's last token (collected during the
    prefill dispatch) and stored host-side — pattern updates are O(keep)
    integer writes, the same "host bookkeeping lags the device" split as
    the paged backend's page-table mirror.
  * The jitted decode step contracts ONLY the listed groups
    (models/transformer._ffn_apply -> core/dsg_linear.swiglu_csr), with
    the CSR row width bucketed to a power of two
    (sparse_mask.active_group_bound) so counts drifting under the "ema"
    threshold never trigger per-count recompiles.
  * Every `refresh_interval` emitted tokens (per lane, so streams are
    invariant to co-scheduling and replica count) the decode step also
    runs `ops.drs_project`/`ops.drs_scores` on the current FFN inputs and
    returns the group scores; the host rewrites the due lanes' patterns
    off the measured decode window.  Between refreshes a lane's pattern
    rides unchanged — the paper's amortization (f(W) every 50 steps)
    applied to serving selection.

Threshold modes ("topk" | "ema") are PER-LANE here: serving lanes are
unrelated requests, so the paper's inter-sample threshold sharing
(threshold_mode="shared", batch row 0) degenerates to per-lane topk; the
online prefill path still honors cfg.dsg.threshold_mode.  "ema" carries a
per-(layer, lane) threshold EMA seeded from the admission topk threshold,
so selection needs no per-refresh sort and counts float with activation
mass.

Free lanes mirror the donor lane's pattern inside the jitted step
(mirror_csr) for the same reason they mirror its token: a paged free lane
writes duplicate K/V into the donor's pages, which is only harmless if
the duplicate is bit-identical — a diverging FFN path would corrupt the
pool.
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import double_mask as dm
from repro.core import drs, sparse_mask


class DSGServingConfig(NamedTuple):
    """Runtime policy knobs (compute-dispatch knobs — which FFN executor
    applies the pattern — live on ModelConfig.dsg_ffn_apply, like
    paged_attn_kernel; sparsity level gamma lives on cfg.dsg)."""
    refresh_interval: int = 8     # emitted tokens between DRS refreshes,
                                  # per lane (1 = re-select every step)
    threshold: str = "topk"       # "topk" | "ema" per-lane selection
    ema_decay: float = 0.95       # threshold EMA decay ("ema" mode)


def as_serving_config(value) -> Optional[DSGServingConfig]:
    """Engine-kwarg coercion: True -> defaults, None/False -> disabled."""
    if value is None or value is False:
        return None
    if value is True:
        return DSGServingConfig()
    if isinstance(value, DSGServingConfig):
        return value
    raise TypeError(
        f"dsg_serving must be a DSGServingConfig, True, or None; got "
        f"{type(value).__name__}")


def mirror_csr(csr: dict, free_mask, donor) -> dict:
    """Overwrite free lanes' CSR rows with the donor lane's (jit-side,
    donor is traced).  csr = {'idx': (L, B, K), 'counts': (L, B)}."""
    idx, counts = csr["idx"], csr["counts"]
    fm = jnp.asarray(free_mask)
    d_idx = jnp.take(idx, donor, axis=1)          # (L, K)
    d_cnt = jnp.take(counts, donor, axis=1)       # (L,)
    return {"idx": jnp.where(fm[None, :, None], d_idx[:, None, :], idx),
            "counts": jnp.where(fm[None, :], d_cnt[:, None], counts)}


def double_mask_csr(norm_fn: Callable[[jax.Array], jax.Array],
                    x: jax.Array, idx: jax.Array, counts: jax.Array,
                    *, block: int, n_groups: int) -> jax.Array:
    """Double-mask selection (core/double_mask.py, paper §2.3) driven by
    a group-CSR pattern: y = Mask(norm(Mask(x))) with the mask expanded
    from the index list.  The decode stack here is pre-norm, which needs
    no DMS (the norm precedes the masked linear — see double_mask.py);
    this is the re-application hook for post-norm stacks, where the norm
    after the block densifies the zeros the CSR selection created."""
    mask = sparse_mask.csr_to_dense(idx, counts, n_groups)
    return dm.double_mask(norm_fn, x, mask, block)


class DSGRuntime:
    """Host-side per-lane DRS state for one ServingEngine.

    Patterns are kept full-width on the host — idx (L, B, G) int32,
    counts (L, B) int32 — and pushed to device sliced to the current pow2
    active-group bound (device_csr caches the pushed arrays per
    (version, bound), invalidated on any pattern write).  All updates are
    numpy: deterministic, cheap (O(L * keep) per lane), and off the
    device stream.
    """

    def __init__(self, cfg, scfg: DSGServingConfig, n_slots: int):
        if not cfg.dsg.enabled:
            raise ValueError("dsg_serving needs cfg.dsg.enabled")
        if cfg.d_ff % cfg.dsg.block:
            raise ValueError(
                f"d_ff={cfg.d_ff} not divisible by DSG block "
                f"{cfg.dsg.block}")
        if scfg.threshold not in ("topk", "ema"):
            raise ValueError(
                f"serving threshold must be 'topk' or 'ema' (per-lane "
                f"modes), got {scfg.threshold!r}")
        if scfg.refresh_interval < 1:
            raise ValueError("refresh_interval must be >= 1")
        self.cfg = scfg
        self.block = cfg.dsg.block
        self.n_groups = cfg.d_ff // cfg.dsg.block
        self.keep = drs.keep_groups(cfg.d_ff, cfg.dsg.drs_cfg())
        self.n_layers = cfg.n_layers
        self.n_slots = n_slots
        shape = (cfg.n_layers, n_slots)
        # every lane starts at the minimal pattern {group 0}: inactive
        # lanes then never inflate the bound, and the in-jit donor mirror
        # makes their actual compute donor-identical anyway
        self.idx = np.zeros(shape + (self.n_groups,), np.int32)
        self.counts = np.ones(shape, np.int32)
        self.ema = np.zeros(shape, np.float32)
        self.lane_active = np.zeros(n_slots, bool)
        self.step_log: List[dict] = []    # per-step FLOP model entries
        self._dev = {}
        self._version = 0

    # -- pattern updates (host) ---------------------------------------------

    def _write_rows(self, lane: int, scores: np.ndarray, seed_ema: bool):
        """scores (L, G) float -> rewrite lane's per-layer CSR rows."""
        g, keep = self.n_groups, self.keep
        for l in range(self.n_layers):
            s = scores[l]
            thr_topk = np.partition(s, g - keep)[g - keep]
            if self.cfg.threshold == "ema" and not seed_ema:
                thr = self.ema[l, lane]
            else:
                thr = thr_topk
            mask = s >= thr
            if not mask.any():          # EMA threshold above every score
                mask[int(np.argmax(s))] = True
            active = np.flatnonzero(mask).astype(np.int32)
            row = np.zeros(g, np.int32)
            row[:len(active)] = active
            self.idx[l, lane] = row
            self.counts[l, lane] = len(active)
            if self.cfg.threshold == "ema":
                self.ema[l, lane] = (thr_topk if seed_ema else
                                     self.cfg.ema_decay * thr
                                     + (1 - self.cfg.ema_decay) * thr_topk)
        self._version += 1
        self._dev.clear()

    def set_lane_from_scores(self, lane: int, scores: np.ndarray):
        """Admission: seed the lane's pattern (and EMA state) from the
        DRS scores of the prompt's last token — the lane decodes sparsely
        from its FIRST step, no dense warm-in."""
        self._write_rows(lane, np.asarray(scores, np.float32),
                         seed_ema=True)
        self.lane_active[lane] = True

    def update_from_scores(self, scores: np.ndarray, lanes):
        """Refresh: scores (L, B, G) from the decode step's collect pass;
        only the DUE lanes' patterns are rewritten (per-lane cadence —
        co-scheduled lanes refreshing on their own token counts keeps
        streams invariant to slot assignment and replica count)."""
        scores = np.asarray(scores, np.float32)
        for i in lanes:
            if self.lane_active[i]:
                self._write_rows(i, scores[:, i], seed_ema=False)

    def reset_lane(self, lane: int):
        """Retirement: drop back to the minimal pattern so a parked lane
        never inflates the group-wide bound."""
        self.idx[:, lane] = 0
        self.counts[:, lane] = 1
        self.ema[:, lane] = 0.0
        self.lane_active[lane] = False
        self._version += 1
        self._dev.clear()

    # -- decode-step operands (device) --------------------------------------

    def bound(self) -> int:
        """Static CSR row width for this step: pow2 bucket over the
        active lanes' counts (mirrors ServingEngine._live_pages)."""
        if self.lane_active.any():
            mc = int(self.counts[:, self.lane_active].max())
        else:
            mc = 1
        return sparse_mask.active_group_bound(mc, self.n_groups)

    def warm_bounds(self) -> tuple:
        """Bounds warm_decode pre-compiles.  "topk" pins every lane at
        exactly `keep` groups (up to score ties), so one bucket suffices;
        "ema" counts float, so every bucket is reachable."""
        if self.cfg.threshold == "topk":
            return (sparse_mask.active_group_bound(self.keep,
                                                   self.n_groups),)
        return sparse_mask.active_group_buckets(self.n_groups)

    def device_csr(self, bound: int) -> dict:
        """Push the pattern state sliced to `bound`, cached per
        (version, bound) so steady decode re-uses the device arrays."""
        key = (self._version, bound)
        if key not in self._dev:
            self._dev[key] = {
                "idx": jnp.asarray(self.idx[:, :, :bound]),
                "counts": jnp.asarray(
                    np.minimum(self.counts, bound).astype(np.int32)),
            }
        return self._dev[key]

    # -- FLOP accounting (benchmarks/bench_dsg_serving.py) -------------------

    def record_step(self, active, bound: int):
        """Log this decode step's modeled FFN group-units: dense = every
        group for every active lane; csr = the per-lane counts the CSR
        kernel walks; bound = what the padded XLA gather contracts (pow2
        bucket, the static-shape overhead)."""
        n = len(active)
        self.step_log.append({
            "active": n,
            "dense_units": self.n_layers * self.n_groups * n,
            "csr_units": int(self.counts[:, list(active)].sum()),
            "bound_units": self.n_layers * bound * n,
        })

    def flop_stats(self) -> dict:
        """Aggregate modeled FFN FLOP reduction over the logged steps."""
        if not self.step_log:
            raise ValueError("no decode steps recorded")
        dense = sum(e["dense_units"] for e in self.step_log)
        csr = sum(e["csr_units"] for e in self.step_log)
        bnd = sum(e["bound_units"] for e in self.step_log)
        return {"steps": len(self.step_log),
                "dense_units": dense, "csr_units": csr,
                "bound_units": bnd,
                "flop_reduction_csr": dense / max(csr, 1),
                "flop_reduction_bound": dense / max(bnd, 1),
                "overhead_bytes": sparse_mask.csr_overhead_bytes(
                    (self.n_layers, self.n_slots), self.n_groups)}
