"""Replica executors: how N serving replicas actually run.

PR 4's Router modeled the data-parallel makespan — replicas were stepped
one after another in-process and the slowest replica's accumulated busy
time stood in for the parallel wall clock.  This module makes the
execution strategy a pluggable choice (the ROADMAP's "real parallel
replica execution" item), the same move Dynasparse makes when it maps
dynamic-sparsity work onto parallel hardware at runtime instead of
simulating the schedule:

  * sequential — PR 4's behavior bit-for-bit: replicas step in index
                 order inside the router tick, per-replica busy time is
                 recorded, and `Router.makespan_seconds()` stays the
                 MODELED number (max busy time).  The reference executor
                 every other mode is differentially tested against.
  * threaded   — one free-running worker thread per replica: each worker
                 drives its own engine's jitted prefill/decode steps
                 (dispatch overlaps device work; JAX releases the GIL
                 inside compiled calls) while the router thread keeps
                 dispatching queued requests against live introspection.
                 Makespan switches to MEASURED wall clock.
  * sharded    — replica steps fuse into ONE device dispatch: per-replica
                 decode operands and KV caches are stacked along a
                 leading replica axis and a single vmapped decode step
                 runs the whole replica group (optionally laid out over a
                 `replicas` mesh axis from `parallel/sharding.py`, so on
                 a multi-device platform each stacked slice lives on its
                 own device).  Makespan is MEASURED wall clock.

Determinism: at `temperature=0` under per-row DRS selection the merged
uid-keyed result stream is invariant to the executor choice — requests
are dispatched whole and every replica is solo-deterministic, so WHERE
and WHEN a request decodes never changes WHAT it decodes
(tests/test_parallel_exec.py pins {sequential, threaded} x {dense,
paged} x {1,2,3} replicas bitwise).  What the threaded executor gives up
is placement reproducibility for SAMPLED traffic: dispatch decisions
react to live timing, so `temperature>0` streams are only reproducible
under the lockstep executors.

The router dispatches against executor-owned `ReplicaProxy` objects, not
engines: a proxy forwards introspection reads (queue_depth / free_slots
/ free_pages / ...) and routes `submit` through the executor so worker
threads are woken when work lands.  Direct engine access stays available
as `proxy.engine` (and `Router.engines`) for warmup and stats code that
runs while no drive is in flight.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import (CheckedCondition, GuardedDeque,
                                      GuardedDict, GuardedList, locked_by,
                                      owned_by, runs_on, tsan_enabled)
from repro.serving import scheduler as sched

EXEC_MODES = ("sequential", "threaded", "sharded")


class ReplicaFailure(RuntimeError):
    """A replica's engine raised during a step.

    Carries WHICH replica (`index`) and the original exception (`cause`)
    so the router's fault-tolerance layer can contain the failure — mark
    the replica, reclaim its requests (serving/router.py).  str() embeds
    the cause message, so fail-fast callers that match on the original
    text (e.g. "engine stalled") keep working when fault tolerance is
    off and the wrapper re-raises."""

    def __init__(self, index: int, cause: BaseException):
        super().__init__(f"replica {index} failed: {cause}")
        self.index = index
        self.cause = cause


class ReplicaProxy:
    """Executor-owned handle for one replica.

    The router's policies and dispatch path talk to proxies only:
    attribute reads and writes forward to the underlying `ServingEngine`
    (so the whole introspection surface — `queue_depth()`,
    `free_slots()`, `free_pages()`, `can_admit_request()`, ... — works
    unchanged), while `submit` routes through the executor, which is
    what lets the threaded executor wake the replica's worker the moment
    work is dispatched to it."""

    __slots__ = ("_executor", "index")

    def __init__(self, executor: "ReplicaExecutor", index: int):
        object.__setattr__(self, "_executor", executor)
        object.__setattr__(self, "index", index)

    @property
    def engine(self):
        """The wrapped ServingEngine (direct access for warmup/stats)."""
        return self._executor.engines[self.index]

    def submit(self, req):
        """Dispatch `req` to this replica through the executor."""
        self._executor.dispatch(self.index, req)

    def __getattr__(self, name):
        return getattr(self._executor.engines[self.index], name)

    def __setattr__(self, name, value):
        setattr(self._executor.engines[self.index], name, value)

    def __repr__(self):
        return (f"ReplicaProxy({self.index}, "
                f"executor={self._executor.name!r})")


class ReplicaExecutor:
    """How a router's replica group executes.

    Concrete executors implement either the lockstep protocol
    (`lockstep=True`: the router tick calls `step_all(indices)` and every
    named replica advances exactly one step before the tick returns) or
    the free-running protocol (`lockstep=False`: `drive(router,
    max_steps)` owns the whole run loop — workers step their replicas
    whenever they have work while the router thread dispatches).

    Timing contract: `busy_seconds[i]` accumulates replica i's stepping
    time; `wall_seconds` accumulates real elapsed time across
    `step_all`/`drive` calls.  `measured` tells the router which number
    `makespan_seconds()` should trust — the modeled max-busy-time for
    the sequential executor, the measured wall clock once replicas truly
    overlap.  `Router.reset_counters()` calls `reset_timing()` after
    warmup so measured windows are steady-state.
    """

    name = "abstract"
    lockstep = True
    #: True when replicas genuinely overlap, so wall_seconds (not the
    #: modeled max busy time) is the data-parallel makespan.
    measured = False

    def __init__(self, engines: Sequence):
        self.engines = list(engines)
        self.proxies = [ReplicaProxy(self, i)
                        for i in range(len(self.engines))]
        self.busy_seconds = [0.0] * len(self.engines)
        self.wall_seconds = 0.0

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, index: int, req):
        """Hand `req` to replica `index` (called from the router thread,
        between ticks for lockstep executors)."""
        self.engines[index].submit(req)

    # -- execution -----------------------------------------------------------

    def step_all(self, indices: Sequence[int]):
        """Advance every replica in `indices` one step (lockstep only)."""
        raise NotImplementedError

    def drive(self, router, max_steps: int):
        """Run the router's whole drain loop (free-running only)."""
        raise NotImplementedError(
            f"{self.name!r} is a lockstep executor; the router drives it "
            f"through step_all()")

    # -- bookkeeping ---------------------------------------------------------

    def reset_timing(self):
        self.busy_seconds = [0.0] * len(self.engines)
        self.wall_seconds = 0.0

    def warm(self, sample: bool = False):
        """Pre-compile any executor-owned jitted callables (the engines'
        own are warmed by workload.warmup_engine).  No-op by default —
        only the sharded executor compiles beyond the engines."""

    def close(self):
        """Release executor resources (worker threads).  Idempotent."""

    @staticmethod
    def has_work(eng) -> bool:
        """Whether an engine has anything left to step: queued requests
        or a resident (non-free) lane.  THE busy predicate — the router
        and every executor share it."""
        return bool(eng.queue) or any(not s.free for s in eng.slots)


class SequentialExecutor(ReplicaExecutor):
    """PR 4's in-process behavior, bit-for-bit: replicas step one after
    another in replica-index order inside the router tick.  Makespan
    stays MODELED (slowest replica's accumulated busy time) — stepping
    is serialized, so wall clock would hide the data-parallel win."""

    name = "sequential"
    lockstep = True
    measured = False

    def step_all(self, indices):
        t0 = time.perf_counter()
        try:
            for i in indices:
                ti = time.perf_counter()
                try:
                    # each engine's step is atomic (begin -> dispatch ->
                    # commit inside), so a failure here never corrupts a
                    # sibling replica's state — replicas after `i` simply
                    # skip this tick, which lockstep never promised anyway
                    self.engines[i].step()
                except BaseException as e:
                    raise ReplicaFailure(i, e) from e
                finally:
                    self.busy_seconds[i] += time.perf_counter() - ti
        finally:
            self.wall_seconds += time.perf_counter() - t0


@locked_by("_cond", "_idle", "_errors", "busy_seconds", "_stop",
           "_progress")
@owned_by("router", "_threads", "wall_seconds")
class ThreadedExecutor(ReplicaExecutor):
    """One free-running worker thread per replica.

    Workers step their own engine whenever it has work (each engine owns
    its jitted callables, and JAX releases the GIL inside compiled
    dispatches, so one replica's host-side scheduling overlaps another's
    device work).  The router thread stays the only dispatcher: it
    re-offers the queue head to the policy against live introspection
    and `dispatch()` wakes the chosen replica's worker.  There is no
    per-tick barrier — a replica draining light requests never waits for
    a sibling grinding a heavy generation.

    Consequences, both pinned by tests/test_parallel_exec.py: greedy
    (`temperature=0`) merged streams are bitwise identical to the
    sequential executor (placement never changes content), and
    `makespan_seconds()` is the MEASURED wall clock of the drive loop.
    Sampled streams are NOT reproducible across runs (placement depends
    on live timing — the engine's per-(step, lane) PRNG schedule sees
    different admission steps), which is the documented trade.

    Worker threads are daemons, started lazily at the first `drive()`
    and parked between runs; call `close()` to join them (long-lived
    apps), or let process exit reap them (tests, benchmarks).
    """

    name = "threaded"
    lockstep = False
    measured = True
    # router safety-net poll: worker -> router wakes ride a sticky Event
    # (set() is never lost, unlike a notify that fires while the router
    # is mid-dispatch), so this only bounds recovery from a crashed
    # worker or an external submit
    _POLL_S = 0.1

    def __init__(self, engines):
        super().__init__(engines)
        # REPRO_TSAN=1 (read once here, like REPRO_INTERPRET at trace
        # time): the Condition learns who holds it and the annotated
        # mutable state asserts the lock/owner discipline on every
        # mutation — the tier-1 suite doubles as a thread sanitizer
        self._tsan = tsan_enabled()
        self._cond = (CheckedCondition() if self._tsan
                      else threading.Condition(threading.RLock()))
        self._router_wake = threading.Event()
        self._idle = [True] * len(self.engines)
        self._errors: List[ReplicaFailure] = []
        # per-replica monotonic stamp of the last completed step — the
        # drive loop's stall-timeout detector compares against it while
        # a worker is busy (fault_tolerance.stall_timeout_s)
        self._progress = [time.perf_counter()] * len(self.engines)
        self._stop = False
        self._threads: Optional[List[threading.Thread]] = None
        if self._tsan:
            self._idle = GuardedList(self._idle, cond=self._cond,
                                     label="ThreadedExecutor._idle")
            self._errors = GuardedList(cond=self._cond,
                                       label="ThreadedExecutor._errors")
            self._progress = GuardedList(
                self._progress, cond=self._cond,
                label="ThreadedExecutor._progress")
            self.busy_seconds = GuardedList(
                self.busy_seconds, cond=self._cond,
                label="ThreadedExecutor.busy_seconds")
            for i, eng in enumerate(self.engines):
                eng.queue = GuardedDeque(eng.queue, cond=self._cond,
                                         label=f"engines[{i}].queue")
                eng.done = GuardedDict(eng.done, cond=self._cond,
                                       label=f"engines[{i}].done")

    def _own_engine(self, i: int, thread):
        """TSAN bookkeeping: resolve the 'worker' role for replica `i` to
        a live thread (claim) or back to quiescent (None — anyone may
        mutate, e.g. warmup/stats on the main thread between drives)."""
        if not self._tsan:
            return
        eng = self.engines[i]
        for obj in (eng.queue, eng.done):
            set_owner = getattr(obj, "set_owner", None)
            if set_owner is not None:
                set_owner(thread)

    # -- dispatch ------------------------------------------------------------

    @runs_on("router")
    def dispatch(self, index, req):
        with self._cond:
            self.engines[index].submit(req)
            self._cond.notify_all()

    # -- worker protocol -----------------------------------------------------

    @runs_on("router")
    def _ensure_threads(self):
        """Start (or re-staff) one worker per replica.  A worker exits
        when its engine raises (the error re-raises in drive), so a
        later run() must replace dead workers; parked live workers are
        kept."""
        with self._cond:
            old = self._threads or [None] * len(self.engines)
            if all(t is not None and t.is_alive() for t in old):
                return
            if not any(t is not None and t.is_alive() for t in old):
                self._stop = False   # fully stopped: safe to restart
            if self._stop:
                return               # close() timed out on a live worker
            self._threads = []
            for i in range(len(self.engines)):
                t = old[i]
                if t is None or not t.is_alive():
                    # start() under the lock is safe: the worker's first
                    # action is to acquire the cond, so it just blocks
                    # until we release
                    t = threading.Thread(target=self._worker, args=(i,),
                                         daemon=True, name=f"replica-{i}")
                    t.start()
                self._threads.append(t)

    @runs_on("worker")
    def _worker(self, i: int):
        eng = self.engines[i]
        while True:
            with self._cond:
                self._own_engine(i, None)     # parked: engine quiescent
                while not self._stop and not self.has_work(eng):
                    self._idle[i] = True
                    self._router_wake.set()
                    self._cond.wait()
                if self._stop:
                    return
                self._idle[i] = False
                # fresh stall clock at the idle->busy transition: the
                # stamp would otherwise date from the last completed
                # step, and a worker woken after a long idle would be
                # falsely suspected before its first step finishes
                self._progress[i] = time.perf_counter()
                self._own_engine(i, threading.current_thread())
            while True:                      # step outside the lock
                done0 = len(eng.done)
                queued0 = len(eng.queue)
                t0 = time.perf_counter()
                try:
                    eng.step()
                except BaseException as e:   # surfaced by the drive loop
                    with self._cond:
                        self._errors.append(ReplicaFailure(i, e))
                        self._idle[i] = True
                        self._own_engine(i, None)
                        self._router_wake.set()
                    return
                dt = time.perf_counter() - t0
                with self._cond:
                    # makespan code reads busy_seconds while workers run;
                    # an unlocked += is a lost-update race between the
                    # read-modify-write and reset_timing's rebind
                    self.busy_seconds[i] += dt
                    self._progress[i] = time.perf_counter()
                # wake the router only on events a policy can act on — a
                # retirement freed a lane, or an admission drained this
                # replica's queue.  Signaling every step would have the
                # router thread and N workers convoying; the sticky Event
                # keeps even an inconveniently-timed wake from being lost.
                if (len(eng.done) != done0 or len(eng.queue) < queued0):
                    self._router_wake.set()
                # observe close() promptly even while work remains —
                # engines are always between steps here, so stopping is
                # state-safe
                if self._stop or not self.has_work(eng):
                    break                    # outer loop parks under lock

    # -- drive ---------------------------------------------------------------

    @runs_on("router")
    def drive(self, router, max_steps: int):
        """Drain the router: dispatch from this (the router's) thread,
        let workers free-run, return when no queued or resident work is
        left.  Worker exceptions re-raise — unless the router opted into
        fault tolerance, in which case they are contained (reclaim +
        re-dispatch, serving/router.py) and the dead worker is
        restaffed; likewise the router-stall error (all workers parked,
        policy still defers the head) degrades to explicit per-request
        failure instead of raising.  With `stall_timeout_s` set, a busy
        worker making no step progress gets its replica marked SUSPECT
        and its engine aborted at the next step boundary."""
        self._ensure_threads()
        ft = getattr(router, "ft", None)
        t0 = time.perf_counter()
        try:
            with self._cond:
                now = time.perf_counter()
                for i in range(len(self.engines)):
                    self._progress[i] = now    # stall clock starts now
                self._cond.notify_all()      # work may predate the drive
            while router.steps < max_steps:
                with self._cond:             # dispatch + parked check are
                    if self._errors:         # atomic vs worker parking
                        err = self._errors.pop(0)
                        if not router._handle_replica_failure(err):
                            raise err
                        # contained: restaff the dead worker (a restarted
                        # replica needs one; a DEAD replica's worker just
                        # parks — routable() keeps it starved) and give
                        # the revived replica a fresh stall clock
                        self._progress[err.index] = time.perf_counter()
                        self._ensure_threads()
                        self._cond.notify_all()
                        router.steps += 1
                        continue
                    if ft is not None and ft.stall_timeout_s is not None:
                        now = time.perf_counter()
                        for i in range(len(self.engines)):
                            if (not self._idle[i]
                                    and now - self._progress[i]
                                    > ft.stall_timeout_s):
                                router._on_replica_stall(i)
                    router._expire_deadlines()
                    router._dispatch()       # safe: RLock is re-entrant
                    all_parked = (all(self._idle) and
                                  not any(self.has_work(e)
                                          for e in self.engines))
                    if all_parked and not router.queue:
                        return               # drained
                    if all_parked and router.queue:
                        if ft is not None:
                            router._fail_undispatchable()
                            router.steps += 1
                            continue
                        raise RuntimeError(
                            f"router stalled: {len(router.queue)} queued "
                            f"request(s) undispatchable by policy "
                            f"{router.policy.name!r} while all replicas "
                            f"are idle; raise cache_tokens or lower "
                            f"max_new/prompt_bucket")
                    router.steps += 1
                # wait OUTSIDE the lock: the sticky Event means a wake
                # that fires between the check and the wait still lands
                self._router_wake.wait(timeout=self._POLL_S)
                self._router_wake.clear()
            # step budget exhausted with work left: stop the workers so
            # the snapshot run() returns is stable (the lockstep
            # executors also stop stepping at the cap); the next run()
            # restarts fresh workers
            self.close()
        finally:
            self.wall_seconds += time.perf_counter() - t0

    @runs_on("router")
    def reset_timing(self):
        """Base behavior under the lock; under TSAN rebinding replaced
        the guarded busy_seconds with a plain list, so re-wrap."""
        with self._cond:
            super().reset_timing()
            if self._tsan:
                self.busy_seconds = GuardedList(
                    self.busy_seconds, cond=self._cond,
                    label="ThreadedExecutor.busy_seconds")

    @runs_on("router")
    def close(self):
        """Idempotent shutdown: signal every worker, join each with a
        bounded timeout, and RAISE naming the workers that failed to
        exit instead of silently leaking their threads.  A straggler is
        a worker stuck inside a single step (device call); _stop stays
        set so it exits at its next step boundary rather than
        resurrecting — call close() again to confirm the shutdown."""
        with self._cond:
            threads = self._threads or ()
            if not threads:
                return                   # already closed: no-op
            self._stop = True
            self._cond.notify_all()
        for t in threads:
            t.join(timeout=5.0)
        stuck = [t.name for t in threads if t.is_alive()]
        if stuck:
            # restarting now could put two workers on one engine, so the
            # executor stays in the stopped state until the straggler
            # exits (a later _ensure_threads checks _stop)
            raise RuntimeError(
                f"ThreadedExecutor.close(): worker thread(s) "
                f"{', '.join(stuck)} did not exit within 5s (stuck "
                f"inside a step); they exit at their next step boundary "
                f"— call close() again to confirm shutdown")
        with self._cond:
            self._threads = None
            self._stop = False


class ShardedExecutor(ReplicaExecutor):
    """One device dispatch for the whole replica group.

    Each lockstep tick runs the host half of every active replica's step
    (`ServingEngine.begin_step()`), stacks the decode operands and KV
    caches along a leading replica axis, executes ONE jitted+vmapped
    decode step (`scheduler.make_decode_fns` — the exact per-engine step
    bodies, vmapped), then unstacks and commits per replica.  With a
    mesh carrying a `replicas` axis (see `parallel.sharding.replica_mesh`)
    the stacked operands are laid out over that axis, so each replica's
    slice lives — and computes — on its own device; without a mesh the
    vmapped step still collapses N dispatches into one, which is the win
    when host dispatch dominates (many small replicas).

    Every tick batches the FULL replica group: engines with no active
    work that tick ride along on a dummy plan (all lanes mirror donor 0
    at position 0 — `warm_decode`'s pattern: the writes land in the
    scratch page / lane bytes the next admission fully overwrites, and
    nothing observes them), so the group step compiles one variant per
    (live-page bucket, sample) instead of one per active-subset size,
    and `warm()` can pre-compile them all (warmup_router calls it).

    Scope and cost, honestly: admission prefills still run per-replica
    on the host between ticks, stack/unstack touches every cache byte
    per tick (on a sharded mesh the slices are device-local so the
    reshuffle does not cross devices), and the static paged walk bound
    is the MAX over the group's live-page buckets (a wider bound reads
    more masked pages; content is unchanged).  This executor is the
    scaling skeleton for replica groups on real device meshes; the
    threaded executor is the general-purpose parallel choice.
    """

    name = "sharded"
    lockstep = True
    measured = True

    def __init__(self, engines, mesh=None):
        super().__init__(engines)
        if any(getattr(e, "dsg_rt", None) is not None for e in engines):
            raise NotImplementedError(
                "sharded executor batches the plain decode step "
                "(scheduler.make_decode_fns); DSG-serving engines "
                "dispatch the CSR/refresh variants inside "
                "ServingEngine.step() — use exec_mode 'sequential' or "
                "'threaded' with dsg_serving")
        self.mesh = mesh
        self._sharding = None
        if mesh is not None:
            if "replicas" not in mesh.axis_names:
                raise ValueError(
                    "sharded executor needs a mesh with a 'replicas' "
                    f"axis (parallel.sharding.replica_mesh), got axes "
                    f"{mesh.axis_names}")
            if self.engines and len(self.engines) % mesh.shape["replicas"]:
                raise ValueError(
                    f"{len(self.engines)} replicas do not divide the "
                    f"mesh's replicas axis ({mesh.shape['replicas']})")
            from jax.sharding import NamedSharding

            from repro.parallel.sharding import replica_stack_spec
            self._sharding = NamedSharding(mesh, replica_stack_spec())
        e0 = self.engines[0]
        if any(e.decode_chunk != e0.decode_chunk for e in self.engines):
            raise ValueError(
                "sharded executor needs a homogeneous decode_chunk "
                "across replicas (the group step is one compiled "
                "variant): got "
                f"{[e.decode_chunk for e in self.engines]}")
        self.chunk = e0.decode_chunk
        shared_p = all(e.params is e0.params for e in self.engines)
        shared_d = all(e.dsg is e0.dsg for e in self.engines)
        p_ax = None if shared_p else 0
        d_ax = None if shared_d else 0
        # params/dsg are immutable across ticks — stack per-replica views
        # ONCE here; only the caches restack per tick
        self._params_in = (e0.params if shared_p
                           else jax.tree_util.tree_map(
                               lambda *ls: self._stack(list(ls)),
                               *[e.params for e in self.engines]))
        self._dsg_in = (e0.dsg if shared_d
                        else jax.tree_util.tree_map(
                            lambda *ls: self._stack(list(ls)),
                            *[e.dsg for e in self.engines]))
        if self.chunk > 1:
            # fused-chunk group step: the chunked bodies vmapped over the
            # replica axis, one dispatch per (chunk x replicas) tick
            cg, cs = sched.make_chunked_decode_fns(e0.cfg, self.chunk,
                                                   e0.max_seq)
            self._jit_greedy = jax.jit(
                jax.vmap(cg, in_axes=(p_ax, d_ax, 0, 0, 0, 0, 0, 0, None)),
                donate_argnums=(3,), static_argnums=(8,))
            self._jit_sample = jax.jit(
                jax.vmap(cs, in_axes=(p_ax, d_ax, 0, 0, 0, 0, 0, 0, None,
                                      0, 0, 0, 0)),
                donate_argnums=(3,), static_argnums=(8,))
        else:
            greedy, sample = sched.make_decode_fns(e0.cfg)
            self._jit_greedy = jax.jit(
                jax.vmap(greedy, in_axes=(p_ax, d_ax, 0, 0, 0, 0, 0, None)),
                donate_argnums=(3,), static_argnums=(7,))
            self._jit_sample = jax.jit(
                jax.vmap(sample,
                         in_axes=(p_ax, d_ax, 0, 0, 0, 0, 0, None,
                                  0, 0, 0, 0)),
                donate_argnums=(3,), static_argnums=(7,))
        # begin-phase failures deferred past the group step (one raise
        # per tick keeps sibling replicas consistent; see step_all)
        self._pending_failures: List[ReplicaFailure] = []

    def _stack(self, leaves):
        x = jnp.stack(leaves)
        if self._sharding is not None:
            x = jax.device_put(x, self._sharding)
        return x

    def _dummy_plan(self, eng) -> sched.StepPlan:
        """Ride-along operands for an engine with no active lanes this
        tick: every lane mirrors donor 0 at position 0, so the decode
        writes land where nothing ever reads (see class docstring)."""
        n = eng.n_slots
        return sched.StepPlan(
            active=[], donor=0,
            tok=np.zeros(n, np.int32), pos=np.zeros(n, np.int32),
            free_mask=np.ones(n, np.bool_),
            temps=np.zeros(n, np.float32), top_ps=np.ones(n, np.float32),
            live_pages=0, sample=False, chunk=eng.decode_chunk,
            eos_ids=np.full(n, -1, np.int32),
            emit_left=np.ones(n, np.int32))

    def _group_step(self, plans, live: int, sample: bool):
        """One vmapped decode over the full group's stacked operands;
        returns host next-tokens, the stacked output caches, and the
        dispatch wall time."""
        engines = self.engines
        t0 = time.perf_counter()
        tok = self._stack([jnp.asarray(p.tok)[:, None] for p in plans])
        pos = self._stack([jnp.asarray(p.pos) for p in plans])
        free = np.stack([p.free_mask for p in plans])
        donor = np.array([p.donor for p in plans], np.int32)
        caches = jax.tree_util.tree_map(
            lambda *ls: self._stack(list(ls)), *[e.cache for e in engines])
        params, dsg = self._params_in, self._dsg_in
        if sample:
            keys = self._stack([e._base_key for e in engines])
            steps = self._stack([jnp.int32(e.steps) for e in engines])
            temps = np.stack([p.temps for p in plans])
            top_ps = np.stack([p.top_ps for p in plans])
            nxt, out = self._jit_sample(params, dsg, tok, caches, pos,
                                        free, donor, live, keys, steps,
                                        temps, top_ps)
        else:
            nxt, out = self._jit_greedy(params, dsg, tok, caches, pos,
                                        free, donor, live)
        nxt_host = np.array(nxt, np.int32)       # one device sync per tick
        return nxt_host, out, time.perf_counter() - t0

    def _group_chunk_step(self, plans, live: int, sample: bool):
        """Fused-chunk analogue of _group_step: one vmapped dispatch runs
        `chunk` scanned micro-steps for every replica.  Returns host
        (blk, flags, next_tok) stacks, the output caches, and the wall."""
        engines = self.engines
        t0 = time.perf_counter()
        tok = self._stack([jnp.asarray(p.tok) for p in plans])
        pos = self._stack([jnp.asarray(p.pos) for p in plans])
        done = np.stack([p.free_mask for p in plans])
        left = np.stack([p.emit_left for p in plans])
        eos = np.stack([p.eos_ids for p in plans])
        caches = jax.tree_util.tree_map(
            lambda *ls: self._stack(list(ls)), *[e.cache for e in engines])
        params, dsg = self._params_in, self._dsg_in
        if sample:
            keys = self._stack([e._base_key for e in engines])
            steps = self._stack([jnp.int32(e.steps) for e in engines])
            temps = np.stack([p.temps for p in plans])
            top_ps = np.stack([p.top_ps for p in plans])
            blk, flags, nxt, out = self._jit_sample(
                params, dsg, tok, caches, pos, done, left, eos, live,
                keys, steps, temps, top_ps)
        else:
            blk, flags, nxt, out = self._jit_greedy(
                params, dsg, tok, caches, pos, done, left, eos, live)
        return (np.asarray(blk), np.asarray(flags),
                np.array(nxt, np.int32), out, time.perf_counter() - t0)

    def step_all(self, indices):
        t0 = time.perf_counter()
        if self._pending_failures:       # deferred from the previous tick
            raise self._pending_failures.pop(0)
        idx = set(indices)
        plans, real, failures = [], [], []
        for i, eng in enumerate(self.engines):
            plan = None
            if i in idx:
                try:
                    plan = eng.begin_step()
                except BaseException as e:
                    # siblings that already began this tick have emitted
                    # tokens — finish the group step WITHOUT the failed
                    # replica (it rides a dummy plan) and raise after
                    # commit, so no sibling double-emits on the retry
                    failures.append(ReplicaFailure(i, e))
            if plan is not None:
                real.append(i)
            plans.append(plan if plan is not None
                         else self._dummy_plan(eng))
        if not real:
            self.wall_seconds += time.perf_counter() - t0
            if failures:
                self._pending_failures.extend(failures[1:])
                raise failures[0]
            return
        live = max(p.live_pages for p in plans)
        sample = any(p.sample for p in plans)
        if self.chunk > 1:
            blk, flags, nxt_host, out, _ = self._group_chunk_step(
                plans, live, sample)
        else:
            nxt_host, out, _ = self._group_step(plans, live, sample)
        wall = time.perf_counter() - t0
        share = wall / len(real)
        for i, plan in enumerate(plans):
            # rebinding is uniform: dummy riders only got scratch
            # scribbles in regions the next admission overwrites
            self.engines[i].cache = jax.tree_util.tree_map(
                lambda x: x[i], out)
            if i in idx and plan.active:
                # decode_seconds gets an equal share of the fused
                # dispatch; busy_seconds gets the full wall (the replica
                # was co-busy for all of it) — makespan uses
                # wall_seconds either way
                if self.chunk > 1:
                    self.engines[i].commit_chunk(plan, blk[i], flags[i],
                                                 nxt_host[i], share)
                else:
                    self.engines[i].commit_step(plan, nxt_host[i], share)
                self.busy_seconds[i] += wall
        self.wall_seconds += wall
        if failures:
            self._pending_failures.extend(failures[1:])
            raise failures[0]

    def warm(self, sample: bool = False):
        """Pre-compile the group step for every live-page bucket this
        executor can reach (the executor analogue of
        `ServingEngine.warm_decode`; warmup_router calls it so no vmapped
        compile lands inside a measured window).  All-dummy plans: the
        dispatched writes are never observed."""
        e0 = self.engines[0]
        if e0.cache.kind == "paged":
            buckets = sched.live_page_buckets(
                e0.max_seq // e0.cache.page_size)
        else:
            buckets = [0]
        plans = [self._dummy_plan(e) for e in self.engines]
        for live in buckets:
            for do_sample in ({False, sample}):
                if self.chunk > 1:
                    *_, out, _ = self._group_chunk_step(plans, live,
                                                        do_sample)
                else:
                    nxt, out, _ = self._group_step(plans, live, do_sample)
                for i in range(len(self.engines)):
                    self.engines[i].cache = jax.tree_util.tree_map(
                        lambda x: x[i], out)


def get_executor(mode, engines, *, mesh=None) -> ReplicaExecutor:
    """Executor factory: name -> fresh executor over `engines`.  Objects
    already implementing the executor protocol pass through (custom
    strategies, e.g. a process pool)."""
    if isinstance(mode, ReplicaExecutor):
        return mode
    if mode == "sequential":
        return SequentialExecutor(engines)
    if mode == "threaded":
        return ThreadedExecutor(engines)
    if mode == "sharded":
        return ShardedExecutor(engines, mesh=mesh)
    raise ValueError(f"unknown exec mode {mode!r}; "
                     f"expected one of {EXEC_MODES}")
