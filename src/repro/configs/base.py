"""Model/config schema shared by all architectures and the launcher."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

from repro.core.dsg_linear import DSGConfig


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | xlstm | zamba | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    rope_theta: float = 1_000_000.0
    act: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False
    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_shared: int = 0         # number of shared (always-on) experts
    moe_d_ff: int = 0           # per-expert hidden dim (fine-grained MoE)
    moe_capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0          # Mamba2 N
    ssm_expand: int = 2
    ssm_heads: int = 0          # Mamba2 heads (d_inner / head_dim)
    ssm_chunk: int = 128
    shared_attn_every: int = 0  # zamba: shared attn block every N mamba blocks
    slstm_every: int = 0        # xlstm: sLSTM block every N layers
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    dec_ratio: int = 8          # dec_len = seq_len // dec_ratio for enc-dec shapes
    # --- VLM ---
    vision_prefix: int = 0      # number of stub patch-embedding positions
    # --- attention ---
    window: int = 0             # sliding-window size (0 = full); used for
                                # sub-quadratic long-context variants
    attn_shard: str = "auto"    # "head" | "seq" | "auto" (head if
                                # n_heads % model_shards == 0, else seq)
    # --- DSG ---
    dsg: DSGConfig = field(default_factory=DSGConfig)
    # --- numerics / execution ---
    dtype: str = "float32"      # activation/param compute dtype
    remat: bool = True          # checkpoint each layer in training
    max_seq: int = 8192         # serving cache allocation default
    # --- perf levers (EXPERIMENTS.md §Perf) ---
    branch_constrain: bool = False   # force TP branch psums at bf16 branch
                                     # boundaries (not inside f32 norm bwd)
    moe_aux: str = "topk"            # "topk" | "probs" (sort-free aux loss)
    seq_sharded_residual: bool = False  # Megatron-SP style: residual stream
                                        # (and remat stash) sharded over seq
    gqa_native: bool = False         # grouped attention einsum instead of
                                     # materializing repeated KV heads
    attn_bf16_scores: bool = False   # QK^T scores and probabilities kept
                                     # bf16 (softmax stats stay f32) —
                                     # halves attention HBM traffic
    paged_attn_kernel: str = "auto"  # paged decode executor: "kernel"
                                     # (Pallas paged_attention, interpret
                                     # on CPU), "xla" (bounded gather
                                     # fallback), "auto" (kernel on TPU)
    dsg_ffn_apply: str = "auto"      # group-CSR serving FFN executor:
                                     # "dense" (masked-dense reference),
                                     # "xla" (bounded gather), "kernel"
                                     # (Pallas CSR walk), "auto" (kernel
                                     # on TPU) — see core/dsg_linear.swiglu_csr
    microbatches: int = 1            # gradient-accumulation microbatches
                                     # (remat stash lives per-microbatch:
                                     # peak activation memory / microbatches)

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


# Smoke-test shape used by per-arch CPU smoke tests.
SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")
