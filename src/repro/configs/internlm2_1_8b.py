"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544.  [arXiv:2403.17297]"""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "internlm2-1.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=24, d_model=2048,
        n_heads=16, n_kv=8, d_ff=8192, vocab=92544, d_head=128,
        rope_theta=1_000_000.0, dtype="bfloat16", attn_bf16_scores=True, microbatches=2,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=256, vocab=256,
        d_head=16, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1))
