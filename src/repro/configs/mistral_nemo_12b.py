"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx.  [hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "mistral-nemo-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv=8, d_ff=14336, vocab=131072, d_head=128,
        rope_theta=1_000_000.0, dtype="bfloat16", attn_bf16_scores=True, microbatches=4,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=256, vocab=256,
        d_head=16, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1))
