"""llama3.2-3b [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-3B]

24 heads do not divide the 16-way model axis -> sequence-parallel
attention sharding (DESIGN.md §6)."""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "llama3.2-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=28, d_model=3072,
        n_heads=24, n_kv=8, d_ff=8192, vocab=128256, d_head=128,
        rope_theta=500_000.0, dtype="bfloat16", attn_bf16_scores=True, microbatches=2,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=256, vocab=256,
        d_head=16, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1))
