"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32, i.e. MHA)
d_ff=8192 vocab=32064.  RoPE SwiGLU.  [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=32, d_model=3072,
        n_heads=32, n_kv=32, d_ff=8192, vocab=32064, d_head=96,
        rope_theta=10_000.0, dtype="bfloat16", attn_bf16_scores=True, microbatches=4,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=256, vocab=256,
        d_head=16, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1))
