"""xlstm-350m [ssm] — 24L d_model=1024 4H vocab=50304, sLSTM + mLSTM
blocks (1 sLSTM per 4 layers), d_ff=0 (blocks carry their own up/down
projections).  [arXiv:2405.04517]

Recurrent (O(1)-state decode) -> runs the long_500k cell.
vocab padded 50304 (divisible by 128 and the 16-way model axis)."""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "xlstm-350m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="xlstm", n_layers=24, d_model=1024,
        n_heads=4, n_kv=4, d_ff=0, vocab=50304, d_head=256,
        rope_theta=0.0, slstm_every=4, dtype="bfloat16",
        attn_bf16_scores=True,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=8),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=2, n_kv=2, vocab=256, d_head=32,
        slstm_every=2, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=32,
                      threshold_mode="shared", mode="mask", n_chunks=1))
