"""Architecture registry: --arch <id> resolves here."""
from repro.configs import (
    deepseek_moe_16b,
    internlm2_1_8b,
    llama3_2_3b,
    llama4_scout_17b_a16e,
    llava_next_34b,
    mistral_nemo_12b,
    phi3_mini_3_8b,
    whisper_large_v3,
    xlstm_350m,
    zamba2_7b,
)
from repro.configs.base import SHAPES, SMOKE_SHAPE, ModelConfig, ShapeConfig, shape_by_name

_MODULES = (
    mistral_nemo_12b, internlm2_1_8b, llama3_2_3b, phi3_mini_3_8b,
    deepseek_moe_16b, llama4_scout_17b_a16e, xlstm_350m, llava_next_34b,
    whisper_large_v3, zamba2_7b,
)

ARCHS = {m.ARCH_ID: m for m in _MODULES}


def get_config(arch_id: str) -> ModelConfig:
    return ARCHS[arch_id].config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return ARCHS[arch_id].smoke_config()


# long_500k requires sub-quadratic attention: only the recurrent/hybrid
# archs run it (DESIGN.md §4); pure full-attention archs record a skip.
LONG_CONTEXT_ARCHS = ("xlstm-350m", "zamba2-7b")


def cell_is_runnable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True
