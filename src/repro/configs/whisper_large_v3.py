"""whisper-large-v3 [audio] — enc-dec, 32+32L d_model=1280 20H d_ff=5120
vocab=51866 (padded to 51872 for the 16-way model axis).
[arXiv:2212.04356]

Conv/mel frontend is a STUB: input_specs provides frame embeddings
(B, S, d).  Decoder length = seq_len // dec_ratio (DESIGN.md §4).
20 heads do not divide the model axis -> sequence-parallel attention.
RoPE replaces the learned positional embeddings (documented
simplification)."""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "whisper-large-v3"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec", n_layers=32, enc_layers=32,
        d_model=1280, n_heads=20, n_kv=20, d_ff=5120, vocab=51872,
        d_head=64, rope_theta=10_000.0, act="gelu", norm="layernorm",
        dec_ratio=8, dtype="bfloat16", attn_bf16_scores=True,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16,
                      score="abs_sum"),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=256, vocab=256, d_head=16, dec_ratio=4, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1,
                      score="abs_sum"))
