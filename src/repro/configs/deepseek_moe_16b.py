"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) vocab=102400,
MoE: 2 shared + 64 routed experts, top-6, fine-grained d_ff_e=1408.
[arXiv:2401.06066]"""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "deepseek-moe-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv=16, d_ff=1408, vocab=102400, d_head=128,
        rope_theta=10_000.0, dtype="bfloat16", attn_bf16_scores=True, microbatches=2, moe_aux="probs",
        moe_experts=64, moe_topk=6, moe_shared=2, moe_d_ff=1408,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
        d_head=16, dtype="float32",
        moe_experts=4, moe_topk=2, moe_shared=1, moe_d_ff=128,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1))
