"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + 1 shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Simplifications (DESIGN.md §10): interleaved RoPE/NoPE layers -> RoPE
everywhere; 40 heads do not divide the model axis -> sequence-parallel
attention."""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "llama4-scout-17b-a16e"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", n_layers=48, d_model=5120,
        n_heads=40, n_kv=8, d_ff=8192, vocab=202048, d_head=128,
        rope_theta=500_000.0, dtype="bfloat16", attn_bf16_scores=True, microbatches=4, moe_aux="probs",
        moe_experts=16, moe_topk=1, moe_shared=1, moe_d_ff=8192,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
        d_head=16, dtype="float32",
        moe_experts=4, moe_topk=1, moe_shared=1, moe_d_ff=128,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1))
