"""zamba2-7b [hybrid] — Mamba2 backbone + ONE weight-shared attention+FFN
block applied every 6 Mamba blocks.  d_model=3584 32H (kv=32) d_ff=14336
vocab=32000 ssm_state=64.  [arXiv:2411.15242]

81 assigned layers realized as 78 Mamba2 blocks (13 groups x 6) + 13
invocations of the shared block (DESIGN.md §10).  Shared attention uses a
4096 sliding window so the hybrid stays sub-quadratic -> runs long_500k."""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "zamba2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="zamba", n_layers=78, d_model=3584,
        n_heads=32, n_kv=32, d_ff=14336, vocab=32000, d_head=112,
        rope_theta=10_000.0, window=4096, dtype="bfloat16", attn_bf16_scores=True, microbatches=4,
        ssm_state=64, ssm_expand=2, ssm_heads=112, ssm_chunk=128,
        shared_attn_every=6,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=256, vocab=256,
        d_head=16, window=16, dtype="float32",
        ssm_state=16, ssm_expand=2, ssm_heads=4, ssm_chunk=16,
        shared_attn_every=2,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=32,
                      threshold_mode="shared", mode="mask", n_chunks=1))
