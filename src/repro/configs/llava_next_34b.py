"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling.  [hf:llava-hf/llava-v1.6-34b-hf]

The vision tower is a STUB per the assignment: input_specs provides
precomputed patch embeddings (B, P, d_model) prepended to the text
sequence.  56 heads do not divide the model axis -> sequence-parallel
attention.  Pure full attention -> long_500k cell skipped."""
from repro.configs.base import ModelConfig
from repro.core.dsg_linear import DSGConfig

ARCH_ID = "llava-next-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv=8, d_ff=20480, vocab=64000, d_head=128,
        rope_theta=5_000_000.0, vision_prefix=2880, dtype="bfloat16", attn_bf16_scores=True, microbatches=4,
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=16),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=256, vocab=256,
        d_head=16, vision_prefix=8, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=64,
                      threshold_mode="shared", mode="mask", n_chunks=1))
