"""Checker 1: host-sync and trace hygiene inside jit boundaries.

Walks every function reachable from a `jax.jit` / `pl.pallas_call`
boundary (via `callgraph.Index`) and flags operations that force a
device sync, concretize a tracer, or silently bake mutable state into a
compiled computation:

  JIT101  `.item()` on a value inside traced code (host sync)
  JIT102  `float()` / `int()` / `bool()` coercion of a traced value
  JIT103  `np.*` call on a traced value (host round-trip; use `jnp.*`)
  JIT104  Python control flow (`if`/`while`/`for`/`assert`) on a traced
          value — jit-root functions only, where the static argument set
          is known from the jit call site
  JIT105  jitted closure reads `self.<attr>` — a mutable engine
          attribute captured at trace time is a silent snapshot
  JIT106  non-hashable static argument (mutable default, or a literal
          list/dict/set passed at a static position)

Taint model (documented in docs/analysis.md): non-static parameters are
traced; taint propagates through arithmetic, comparisons, subscripts,
and whitelisted array methods (`astype`, `sum`, `at[...]`, ...), and is
killed by attribute access (`x.shape`, `cfg.vocab`, `handle.kind` are
static) and by shape-reading calls (`len`, `isinstance`).  For functions
reachable from — but not directly at — a jit boundary the static set is
unknown, so two reductions apply: only parameters the body itself uses
as arrays (fed to `jnp`/`lax` ops or array methods) seed the taint, and
the branching check (JIT104) is skipped — config-driven Python branches
are the norm below the boundary.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.callgraph import Index, JitRoot, dotted
from repro.analysis.findings import Finding

CHECKER = "jit_hygiene"

# array methods that return a traced value from a traced receiver
_TRACER_METHODS = {
    "astype", "reshape", "transpose", "ravel", "flatten", "squeeze",
    "sum", "max", "min", "mean", "prod", "cumsum", "cumprod", "dot",
    "clip", "round", "sort", "argsort", "argmax", "argmin", "at", "set",
    "add", "multiply", "get", "take", "repeat", "swapaxes", "conj",
    "real", "imag", "T",
}
# calls whose result is static regardless of argument taint
_KILLER_CALLS = {"len", "isinstance", "hasattr", "getattr", "type",
                 "range", "enumerate", "zip"}
_COERCIONS = {"float", "int", "bool", "complex"}


class _Taint:
    """Syntactic taint evaluation over one function body."""

    def __init__(self, tainted: Set[str]):
        self.names = set(tainted)

    def expr(self, node) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, (ast.BinOp,)):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.Compare):
            return self.expr(node.left) or any(self.expr(c)
                                               for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, ast.IfExp):
            return (self.expr(node.test) or self.expr(node.body)
                    or self.expr(node.orelse))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, ast.Attribute):
            # x.shape / cfg.vocab / handle.kind are static reads — taint
            # survives only through whitelisted array methods, handled
            # at the Call below
            return False
        if isinstance(node, ast.Call):
            name = dotted(node.func) or ""
            base = name.split(".")[0]
            if base in _KILLER_CALLS:
                return False
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _TRACER_METHODS:
                if self.expr(node.func.value) \
                        or self._receiver_chain_tainted(node.func.value):
                    return True
            if base in ("jnp", "jax", "lax"):
                return any(self.expr(a) for a in node.args) \
                    or any(self.expr(k.value) for k in node.keywords)
            return any(self.expr(a) for a in node.args)
        return False

    def _receiver_chain_tainted(self, node) -> bool:
        """x.at[i].set(v): the receiver is Subscript(Attribute(x,'at'));
        walk attribute/subscript chains back to the base name."""
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return isinstance(node, ast.Name) and node.id in self.names

    def assign(self, node):
        if isinstance(node, ast.Assign):
            tainted = self.expr(node.value)
            for tgt in node.targets:
                self._bind(tgt, tainted)
        elif isinstance(node, ast.AugAssign):
            if isinstance(node.target, ast.Name):
                if self.expr(node.value):
                    self.names.add(node.target.id)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            self._bind(node.target, self.expr(node.value))

    def _bind(self, tgt, tainted: bool):
        if isinstance(tgt, ast.Name):
            if tainted:
                self.names.add(tgt.id)
            else:
                self.names.discard(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self._bind(e, tainted)


def _param_names(fn) -> List[str]:
    args = fn.args
    return [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]


def _array_used_names(fn) -> Set[str]:
    """Names the function body treats as arrays: passed bare as the
    FIRST (data) argument of a jnp/jax/lax call, or receiving a
    whitelisted array method.  Trailing positional args are often static
    by contract (lax.top_k's k, axis numbers, shapes) — seeding them
    would flag legal host math on static scalars."""
    used: Set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func) or ""
        if name.split(".")[0] in ("jnp", "jax", "lax") and node.args:
            base = node.args[0]
            while isinstance(base, (ast.Subscript,)):
                base = base.value
            if isinstance(base, ast.Name):
                used.add(base.id)
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _TRACER_METHODS:
            base = node.func.value
            while isinstance(base, (ast.Attribute, ast.Subscript)):
                base = base.value
            if isinstance(base, ast.Name):
                used.add(base.id)
    return used


def _finding(fi, node, code, msg) -> Finding:
    return Finding(file=fi.module.relpath, line=node.lineno,
                   col=getattr(node, "col_offset", 0), code=code,
                   checker=CHECKER, message=msg, context=fi.qualname)


def check(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    roots = index.jit_roots()
    root_by_qual = {r.func.qualname: r for r in roots}
    traced = index.traced_functions(roots)
    for qual, fi in sorted(traced.items()):
        root = root_by_qual.get(qual)
        findings.extend(_check_function(fi, root))
    for root in roots:
        findings.extend(_check_static_args(root))
    return findings


def _check_function(fi, root: Optional[JitRoot]) -> List[Finding]:
    fn = fi.node
    if isinstance(fn, ast.Lambda):
        return []
    params = _param_names(fn)
    statics = root.static_params() if root is not None else set()
    tainted = {p for p in params if p not in statics and p != "self"}
    if root is None:
        # below the boundary the static set is unknown: seed taint only
        # from params the body itself treats as arrays
        tainted &= _array_used_names(fn)
    taint = _Taint(tainted)
    out: List[Finding] = []
    is_root = root is not None

    closure_self_ok = "self" in params

    def walk(body):
        for stmt in body:
            _visit_stmt(stmt)

    def _visit_stmt(stmt):
        # nested defs are traced via their own reachability entry
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            _scan_expr(stmt)
            taint.assign(stmt)
            return
        if is_root and isinstance(stmt, (ast.If, ast.While)) \
                and taint.expr(stmt.test):
            out.append(_finding(
                fi, stmt, "JIT104",
                "Python branch on a traced value concretizes the tracer; "
                "use lax.cond/jnp.where or make the operand static"))
        elif is_root and isinstance(stmt, ast.Assert) \
                and taint.expr(stmt.test):
            out.append(_finding(
                fi, stmt, "JIT104",
                "assert on a traced value concretizes the tracer"))
        elif is_root and isinstance(stmt, ast.For) \
                and taint.expr(stmt.iter):
            out.append(_finding(
                fi, stmt, "JIT104",
                "Python loop over a traced value concretizes the tracer; "
                "use lax.scan/fori_loop"))
        if _is_compound(stmt):
            # scan only the header expressions here; nested statements
            # are visited (and scanned) by the recursion below
            for header in ("test", "iter", "target"):
                expr = getattr(stmt, header, None)
                if expr is not None and not isinstance(expr, list):
                    _scan_expr(expr)
            for item in getattr(stmt, "items", []):
                _scan_expr(item.context_expr)
            for attr in ("body", "orelse", "finalbody"):
                walk(getattr(stmt, attr, []))
            for h in getattr(stmt, "handlers", []):
                walk(h.body)
        else:
            _scan_expr(stmt)

    def _is_compound(stmt):
        return isinstance(stmt, (ast.If, ast.While, ast.For, ast.With,
                                 ast.Try))

    def _scan_expr(stmt):
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not isinstance(node, ast.Call):
                if not closure_self_ok and isinstance(node, ast.Attribute) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id == "self":
                    out.append(_finding(
                        fi, node, "JIT105",
                        f"jitted closure reads self.{node.attr}: mutable "
                        f"engine state captured at trace time is a silent "
                        f"snapshot; pass it as an argument"))
                continue
            name = dotted(node.func) or ""
            # JIT101: .item()
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item":
                out.append(_finding(
                    fi, node, "JIT101",
                    ".item() inside traced code forces a host sync"))
                continue
            # JIT102: float()/int()/bool() of a traced value
            if name in _COERCIONS and node.args \
                    and taint.expr(node.args[0]):
                out.append(_finding(
                    fi, node, "JIT102",
                    f"{name}() coercion of a traced value forces a host "
                    f"sync; use jnp casts or keep the value on device"))
                continue
            # JIT103: np.* on a traced value
            if name.split(".")[0] == "np" and any(
                    taint.expr(a) for a in node.args):
                out.append(_finding(
                    fi, node, "JIT103",
                    f"{name}(...) on a traced value round-trips through "
                    f"the host; use the jnp equivalent"))

    walk(fn.body)
    return out


def _check_static_args(root: JitRoot) -> List[Finding]:
    """JIT106: static args must be hashable — flag mutable defaults on
    static params."""
    fn = root.func.node
    if isinstance(fn, ast.Lambda):
        return []
    out: List[Finding] = []
    statics = root.static_params()
    args = fn.args
    pos = args.posonlyargs + args.args
    defaults = args.defaults
    pairs = list(zip(pos[len(pos) - len(defaults):], defaults))
    pairs += [(a, d) for a, d in zip(args.kwonlyargs, args.kw_defaults)
              if d is not None]
    for arg, default in pairs:
        if arg.arg not in statics:
            continue
        bad = isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
            isinstance(default, ast.Call)
            and (dotted(default.func) or "") in
            ("list", "dict", "set", "np.array", "np.asarray",
             "np.zeros", "np.ones", "jnp.array", "jnp.zeros",
             "jnp.ones"))
        if bad:
            out.append(_finding(
                root.func, default, "JIT106",
                f"static argument {arg.arg!r} has a non-hashable default; "
                f"static args are dict keys in jax's compilation cache"))
    return out
