"""AST module index + jit-boundary reachability for repro-lint.

The checkers share one picture of the code: every function definition in
the analyzed tree (nested defs included), where names imported into each
module resolve to, which functions are *jit roots* (passed to `jax.jit`
or `pl.pallas_call`, directly or through `jax.vmap` / `functools.partial`
/ decorator forms), and which functions are *traced* — reachable from a
root through calls the index can resolve repo-locally.

Resolution is deliberately best-effort and syntactic: `api.decode_step`
resolves through the module's imports, `self.method()` resolves inside
the enclosing class, and the repo's tuple-unpack idiom

    _decode_greedy, _decode_sample = make_decode_fns(cfg)
    self._jit_decode_greedy = jax.jit(_decode_greedy, static_argnums=(7,))

resolves because the index records which nested defs a function returns.
Anything it cannot resolve it drops silently — the checkers trade recall
for zero-configuration precision (docs/analysis.md spells out the
contract).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple


@dataclass
class FunctionInfo:
    qualname: str                 # "pkg.mod.Class.method" / "pkg.mod.fn.inner"
    local: str                    # qualname without the module prefix
    node: ast.AST                 # FunctionDef | AsyncFunctionDef | Lambda
    module: "ModuleInfo"
    class_name: Optional[str] = None
    parent: Optional[str] = None  # enclosing function's local qualname
    returned_inner: Tuple[str, ...] = ()   # local names of returned nested defs


@dataclass
class JitRoot:
    func: FunctionInfo
    kind: str                     # "jit" | "pallas"
    static_argnums: Tuple[int, ...] = ()
    static_argnames: Tuple[str, ...] = ()
    call_line: int = 0

    def static_params(self) -> Set[str]:
        """Parameter names the jit boundary treats as static."""
        node = self.func.node
        if isinstance(node, ast.Lambda):
            args = node.args
        else:
            args = node.args
        names = [a.arg for a in args.posonlyargs + args.args]
        out = set(self.static_argnames)
        for i in self.static_argnums:
            if 0 <= i < len(names):
                out.add(names[i])
        return out


@dataclass
class ModuleInfo:
    path: Path
    relpath: str                  # analysis-root-relative, for findings
    modname: str                  # dotted module name
    tree: ast.Module
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    classes: Dict[str, ast.ClassDef] = field(default_factory=dict)
    imports: Dict[str, str] = field(default_factory=dict)  # alias -> dotted


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Index:
    """Cross-module function index over a set of Python files."""

    def __init__(self):
        self.modules: Dict[str, ModuleInfo] = {}       # modname -> info
        self.functions: Dict[str, FunctionInfo] = {}   # qualname -> info

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, root, files: Optional[List[Path]] = None) -> "Index":
        root = Path(root)
        idx = cls()
        if files is None:
            files = sorted(p for p in root.rglob("*.py")
                           if "__pycache__" not in p.parts)
        for path in files:
            rel = path.relative_to(root)
            modname = ".".join(rel.with_suffix("").parts)
            if modname.endswith(".__init__"):
                modname = modname[:-len(".__init__")]
            try:
                tree = ast.parse(path.read_text(encoding="utf-8"))
            except SyntaxError:
                continue
            mi = ModuleInfo(path=path, relpath=str(rel), modname=modname,
                            tree=tree)
            idx._index_module(mi)
            idx.modules[modname] = mi
        return idx

    def _index_module(self, mi: ModuleInfo):
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mi.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:          # relative import
                    parts = mi.modname.split(".")
                    parts = parts[:len(parts) - node.level]
                    base = ".".join(parts + ([node.module]
                                             if node.module else []))
                for a in node.names:
                    if a.name == "*":
                        continue
                    mi.imports[a.asname or a.name] = f"{base}.{a.name}"

        def visit(body, prefix: str, class_name: Optional[str],
                  parent: Optional[str]):
            for node in body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    local = f"{prefix}{node.name}"
                    fi = FunctionInfo(
                        qualname=f"{mi.modname}.{local}", local=local,
                        node=node, module=mi, class_name=class_name,
                        parent=parent)
                    fi.returned_inner = self._returned_inner(node)
                    mi.functions[local] = fi
                    self.functions[fi.qualname] = fi
                    visit(node.body, f"{local}.", class_name, local)
                elif isinstance(node, ast.ClassDef):
                    mi.classes[node.name] = node
                    visit(node.body, f"{node.name}.", node.name, parent)
                elif isinstance(node, (ast.If, ast.Try, ast.With, ast.For,
                                       ast.While)):
                    # defs nested under control flow keep the same prefix
                    visit(getattr(node, "body", []), prefix, class_name,
                          parent)
                    visit(getattr(node, "orelse", []), prefix, class_name,
                          parent)
                    visit(getattr(node, "finalbody", []), prefix,
                          class_name, parent)
                    for h in getattr(node, "handlers", []):
                        visit(h.body, prefix, class_name, parent)

        visit(mi.tree.body, "", None, None)

    @staticmethod
    def _returned_inner(fn) -> Tuple[str, ...]:
        """Local names of nested defs this function returns (supports
        `return inner` and `return inner_a, inner_b`)."""
        inner = {n.name for n in fn.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            val = node.value
            names: List[str] = []
            elts = val.elts if isinstance(val, ast.Tuple) else [val]
            for e in elts:
                if isinstance(e, ast.Name) and e.id in inner:
                    names.append(e.id)
                else:
                    break
            else:
                if names:
                    return tuple(names)
        return ()

    # -- name resolution -----------------------------------------------------

    def resolve_function(self, mi: ModuleInfo, name: str,
                         scope: Optional[str] = None,
                         class_name: Optional[str] = None
                         ) -> Optional[FunctionInfo]:
        """Resolve a (possibly dotted) name used in module `mi` inside
        function `scope` to a FunctionInfo, or None."""
        # nested def in the enclosing function chain
        cur = scope
        while cur is not None:
            fi = mi.functions.get(f"{cur}.{name}")
            if fi is not None:
                return fi
            cur = mi.functions[cur].parent if cur in mi.functions else None
        # method of the enclosing class
        if class_name and f"{class_name}.{name}" in mi.functions:
            return mi.functions[f"{class_name}.{name}"]
        # module-level def
        if name in mi.functions:
            return mi.functions[name]
        # imported: "api.decode_step" or direct "from x import fn"
        parts = name.split(".")
        head = parts[0]
        target = mi.imports.get(head)
        if target is None:
            return None
        full = ".".join([target] + parts[1:])
        # full is e.g. "repro.models.api.decode_step": split module/attr
        for cut in range(len(full.split(".")), 0, -1):
            modname = ".".join(full.split(".")[:cut])
            rest = ".".join(full.split(".")[cut:])
            m = self.modules.get(modname)
            if m is not None:
                return m.functions.get(rest) if rest else None
        return None

    # -- jit roots -----------------------------------------------------------

    JIT_NAMES = {"jax.jit", "jit"}
    PALLAS_NAMES = {"pl.pallas_call", "pallas_call"}
    WRAPPERS = {"jax.vmap", "vmap", "partial", "functools.partial",
                "jax.pmap", "pmap"}

    def jit_roots(self) -> List[JitRoot]:
        roots: Dict[str, JitRoot] = {}
        for mi in self.modules.values():
            for scope, call, deco_target in self._jit_sites(mi):
                fn_expr, statics = self._unwrap_jit(call)
                if deco_target is not None:
                    fi = deco_target
                else:
                    fi = self._resolve_fn_expr(mi, scope, fn_expr)
                if fi is None:
                    continue
                kind = ("pallas"
                        if self._callee_name(call) in self.PALLAS_NAMES
                        else "jit")
                root = JitRoot(func=fi, kind=kind,
                               static_argnums=statics[0],
                               static_argnames=statics[1],
                               call_line=getattr(call, "lineno", 0))
                roots.setdefault(fi.qualname, root)
        return list(roots.values())

    def _callee_name(self, call: ast.Call) -> Optional[str]:
        return dotted(call.func)

    def _jit_sites(self, mi: ModuleInfo):
        """Yield (enclosing_scope, call_node, decorated_fn|None) for every
        jax.jit / pl.pallas_call site, including decorator forms."""
        # decorator forms
        for fi in mi.functions.values():
            node = fi.node
            for deco in getattr(node, "decorator_list", []):
                name = dotted(deco) or ""
                if name in self.JIT_NAMES:
                    fake = ast.Call(func=deco, args=[], keywords=[])
                    ast.copy_location(fake, deco)
                    yield fi.parent, fake, fi
                elif isinstance(deco, ast.Call):
                    dname = dotted(deco.func) or ""
                    if dname in self.JIT_NAMES:
                        yield fi.parent, deco, fi
                    elif dname in ("partial", "functools.partial") \
                            and deco.args \
                            and (dotted(deco.args[0]) or "") \
                            in self.JIT_NAMES:
                        yield fi.parent, deco, fi
        # call forms: jax.jit(fn, ...) / pl.pallas_call(kernel, ...)
        for scope, fnode in [(None, mi.tree)] + [
                (fi.local, fi.node) for fi in mi.functions.values()]:
            for node in ast.walk(fnode):
                if not isinstance(node, ast.Call):
                    continue
                name = self._callee_name(node) or ""
                if name in self.JIT_NAMES or name in self.PALLAS_NAMES:
                    yield scope, node, None

    def _unwrap_jit(self, call: ast.Call):
        """(fn_expr, (static_argnums, static_argnames)) from a jit-ish
        call, unwrapping partial/vmap."""
        nums: Tuple[int, ...] = ()
        names: Tuple[str, ...] = ()
        for kw in call.keywords:
            if kw.arg == "static_argnums":
                nums = self._const_ints(kw.value)
            elif kw.arg == "static_argnames":
                names = self._const_strs(kw.value)
        fn_expr = None
        args = list(call.args)
        # partial(jax.jit, ...) decorator: no fn arg beyond jax.jit itself
        if args and (dotted(args[0]) or "") in self.JIT_NAMES:
            args = args[1:]
        if args:
            fn_expr = args[0]
        return fn_expr, (nums, names)

    def _resolve_fn_expr(self, mi: ModuleInfo, scope, expr
                         ) -> Optional[FunctionInfo]:
        if expr is None:
            return None
        if isinstance(expr, ast.Call):
            name = self._callee_name(expr) or ""
            if name in self.WRAPPERS and expr.args:
                return self._resolve_fn_expr(mi, scope, expr.args[0])
            return None
        name = dotted(expr)
        if name is None:
            return None
        fi = self.resolve_function(mi, name, scope=scope)
        if fi is not None:
            return fi
        # tuple-unpack binding: greedy, sample = make_decode_fns(cfg)
        return self._tuple_unpack_binding(mi, scope, name)

    def _tuple_unpack_binding(self, mi: ModuleInfo, scope, name: str
                              ) -> Optional[FunctionInfo]:
        """Resolve `name` bound by `a, b = f(...)` where f returns its
        nested defs, anywhere in the enclosing scope chain (or module
        body for scope None)."""
        bodies = []
        cur = scope
        while cur is not None and cur in mi.functions:
            bodies.append(mi.functions[cur].node)
            cur = mi.functions[cur].parent
        bodies.append(mi.tree)
        for body in bodies:
            for node in ast.walk(body):
                if not isinstance(node, ast.Assign) \
                        or not isinstance(node.value, ast.Call):
                    continue
                targets = node.targets[0]
                elts = (targets.elts if isinstance(targets, ast.Tuple)
                        else [targets])
                tnames = [e.id if isinstance(e, ast.Name) else None
                          for e in elts]
                if name not in tnames:
                    continue
                callee = self._callee_name(node.value)
                if callee is None:
                    continue
                producer = self.resolve_function(mi, callee, scope=scope)
                if producer is None or not producer.returned_inner:
                    continue
                pos = tnames.index(name)
                if pos < len(producer.returned_inner):
                    inner_local = (f"{producer.local}."
                                   f"{producer.returned_inner[pos]}")
                    return producer.module.functions.get(inner_local)
        return None

    @staticmethod
    def _const_ints(node) -> Tuple[int, ...]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return (node.value,)
        if isinstance(node, (ast.Tuple, ast.List)):
            return tuple(e.value for e in node.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
        return ()

    @staticmethod
    def _const_strs(node) -> Tuple[str, ...]:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return (node.value,)
        if isinstance(node, (ast.Tuple, ast.List)):
            return tuple(e.value for e in node.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, str))
        return ()

    # -- reachability --------------------------------------------------------

    def traced_functions(self, roots: List[JitRoot]
                         ) -> Dict[str, FunctionInfo]:
        """Functions reachable from the jit roots through resolvable
        calls — the set the tracer actually walks."""
        seen: Dict[str, FunctionInfo] = {}
        work = [r.func for r in roots]
        while work:
            fi = work.pop()
            if fi.qualname in seen:
                continue
            seen[fi.qualname] = fi
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted(node.func)
                if name is None:
                    continue
                if name.startswith("self."):
                    callee = self.resolve_function(
                        fi.module, name[len("self."):],
                        scope=fi.local, class_name=fi.class_name)
                else:
                    callee = self.resolve_function(fi.module, name,
                                                   scope=fi.local)
                if callee is not None:
                    work.append(callee)
        return seen
