"""Checker 4: dataclasses crossing a jit boundary must be pytrees.

A bare `@dataclass` handed to (or built inside) a jitted function is a
trace-time error at best and a silent leaf-capture at worst.  The repo's
convention is `CacheHandle`'s: `@jax.tree_util.register_pytree_node_class`
with static aux data riding in the treedef.

  PYT401  dataclass CONSTRUCTED inside a traced function without a
          pytree registration (the constructed value is what crosses
          the boundary back out; annotations alone don't count — a
          hashable config passed as a static argument is legal)

"Traced" is `callgraph.Index.traced_functions` — everything reachable
from a `jax.jit` / `pl.pallas_call` boundary.  A registration counts if
the class is decorated with `register_pytree_node_class` /
`register_dataclass`, or the module calls `register_pytree_node` /
`register_pytree_with_keys` / `register_dataclass` with the class.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.callgraph import Index, dotted
from repro.analysis.findings import Finding

CHECKER = "pytrees"

_REGISTER_DECOS = {"register_pytree_node_class", "register_dataclass"}
_REGISTER_CALLS = {"register_pytree_node", "register_pytree_with_keys",
                   "register_dataclass", "register_pytree_node_class"}


def _dataclasses(mi) -> Dict[str, ast.ClassDef]:
    out = {}
    for name, cls in mi.classes.items():
        for deco in cls.decorator_list:
            target = deco.func if isinstance(deco, ast.Call) else deco
            if (dotted(target) or "").split(".")[-1] == "dataclass":
                out[name] = cls
    return out


def _registered(mi, cls: ast.ClassDef) -> bool:
    for deco in cls.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        if (dotted(target) or "").split(".")[-1] in _REGISTER_DECOS:
            return True
    for node in ast.walk(mi.tree):
        if isinstance(node, ast.Call) \
                and (dotted(node.func) or "").split(".")[-1] \
                in _REGISTER_CALLS \
                and node.args and isinstance(node.args[0], ast.Name) \
                and node.args[0].id == cls.name:
            return True
    return False


def check(index: Index) -> List[Finding]:
    # (module, class name) -> registered?
    dataclass_reg: Dict[Tuple[str, str], bool] = {}
    for mi in index.modules.values():
        for name, cls in _dataclasses(mi).items():
            dataclass_reg[(mi.modname, name)] = _registered(mi, cls)

    findings: List[Finding] = []
    reported: Set[Tuple[str, str, str]] = set()
    roots = index.jit_roots()
    for qual, fi in sorted(index.traced_functions(roots).items()):
        mi = fi.module
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if not name:
                continue
            key = _resolve_class(index, mi, name)
            if key is None or key not in dataclass_reg:
                continue
            if dataclass_reg[key]:
                continue
            dedup = (qual, key[0], key[1])
            if dedup in reported:
                continue
            reported.add(dedup)
            findings.append(Finding(
                file=mi.relpath, line=node.lineno, col=node.col_offset,
                code="PYT401", checker=CHECKER,
                message=(f"dataclass {key[1]} crosses a jit boundary but "
                         f"is not a registered pytree "
                         f"(@jax.tree_util.register_pytree_node_class)"),
                context=qual))
    return findings


def _resolve_class(index: Index, mi, name: str):
    """(modname, classname) for a class referenced as `name` in `mi`."""
    if name in mi.classes:
        return (mi.modname, name)
    parts = name.split(".")
    target = mi.imports.get(parts[0])
    if target is None:
        return None
    full = ".".join([target] + parts[1:])
    bits = full.split(".")
    modname, clsname = ".".join(bits[:-1]), bits[-1]
    m = index.modules.get(modname)
    if m is not None and clsname in m.classes:
        return (modname, clsname)
    return None
