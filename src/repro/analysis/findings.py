"""Finding record + baseline workflow for repro-lint.

A `Finding` pins a violation to a file/line plus a stable *fingerprint*
(file, code, enclosing definition, message) that survives unrelated
edits moving the line around.  The baseline file
(`scripts/lint_baseline.json`) holds fingerprints of ACCEPTED findings —
each with a human-written reason — so `run_lint.py --fail-on-new` gates
on regressions without forcing every historical acceptance to block CI.

Workflow (docs/analysis.md):

  * fix the finding (preferred), or
  * accept it: `scripts/run_lint.py --write-baseline`, then edit the
    generated entry's `"reason"` field — empty reasons are themselves a
    lint error, so acceptances stay reviewed.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Sequence


@dataclass(frozen=True)
class Finding:
    file: str          # path relative to the analysis root
    line: int
    col: int
    code: str          # e.g. "JIT101"
    checker: str       # e.g. "jit_hygiene"
    message: str
    context: str = ""  # enclosing qualname ("module.Class.method")

    @property
    def fingerprint(self) -> str:
        return f"{self.file}::{self.code}::{self.context}::{self.message}"

    def render(self) -> str:
        where = f"{self.file}:{self.line}:{self.col}"
        ctx = f" [{self.context}]" if self.context else ""
        return f"{where}: {self.code} {self.message}{ctx}"

    def as_dict(self) -> dict:
        return {"file": self.file, "line": self.line, "col": self.col,
                "code": self.code, "checker": self.checker,
                "message": self.message, "context": self.context}


@dataclass
class Baseline:
    """Accepted findings, keyed by fingerprint."""

    entries: Dict[str, dict] = field(default_factory=dict)

    def accepts(self, finding: Finding) -> bool:
        return finding.fingerprint in self.entries

    def split(self, findings: Sequence[Finding]):
        """(new, accepted) partition of `findings`."""
        new = [f for f in findings if not self.accepts(f)]
        accepted = [f for f in findings if self.accepts(f)]
        return new, accepted

    def stale(self, findings: Sequence[Finding]) -> List[str]:
        """Baselined fingerprints no longer produced — candidates for
        removal (the accepted violation was fixed)."""
        live = {f.fingerprint for f in findings}
        return [fp for fp in self.entries if fp not in live]

    def unreasoned(self) -> List[str]:
        return [fp for fp, e in self.entries.items()
                if not str(e.get("reason", "")).strip()]


def load_baseline(path) -> Baseline:
    path = Path(path)
    if not path.exists():
        return Baseline()
    raw = json.loads(path.read_text(encoding="utf-8"))
    entries = {e["fingerprint"]: e for e in raw.get("accepted", [])}
    return Baseline(entries)


def write_baseline(path, findings: Sequence[Finding],
                   previous: Baseline = None) -> None:
    """Write every current finding as an accepted entry, carrying over
    reasons from `previous` where the fingerprint survived."""
    prev = previous.entries if previous else {}
    accepted = []
    for f in sorted(findings, key=lambda f: f.fingerprint):
        entry = {"fingerprint": f.fingerprint,
                 "file": f.file, "code": f.code, "context": f.context,
                 "message": f.message,
                 "reason": prev.get(f.fingerprint, {}).get("reason", "")}
        accepted.append(entry)
    payload = {"_comment": ("repro-lint accepted findings; every entry "
                            "needs a non-empty 'reason' "
                            "(see docs/analysis.md)"),
               "accepted": accepted}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n",
                          encoding="utf-8")
