"""repro-lint: repo-specific static analysis + runtime sanitizer.

The serving stack's correctness rests on invariants that used to hold
only by reviewer convention: host syncs must stay out of the jitted
decode path, the `ThreadedExecutor`/`Router`/`ServingEngine` trio share
mutable state under an informal `_cond` lock discipline, Pallas
BlockSpecs/grids must stay shape-static, and dataclasses crossing a jit
boundary must be registered pytrees.  This package checks all four
mechanically:

  * `contracts`        — the annotation vocabulary (`locked_by`,
                         `owned_by`, `runs_on`, `exempt`) plus the
                         `REPRO_TSAN=1` runtime shim (`CheckedCondition`
                         and guarded containers) that turns tier-1 runs
                         into a dynamic lock-discipline check.
  * `callgraph`        — AST module index + jit-boundary reachability
                         shared by the checkers.
  * `jit_hygiene`      — host syncs / tracer branching / mutable-closure
                         capture / non-hashable statics inside traced
                         code.
  * `locks`            — every mutation of an annotated field is under
                         the declared lock or on the declared owner.
  * `pallas_contracts` — shape-static grids/index_maps; interpret mode
                         is read only via `kernels.ops._interpret()`.
  * `pytrees`          — dataclasses crossing a jit boundary are
                         registered pytrees.

`scripts/run_lint.py` is the CLI (baseline workflow, CI gate); see
docs/analysis.md for the full contract.
"""
from repro.analysis.findings import Finding, load_baseline  # noqa: F401
from repro.analysis.runner import run_lint  # noqa: F401
