"""repro-lint orchestration: build one Index, run every checker.

`run_lint(root)` is the library entry point (tests/test_analysis.py
drives it over fixture corpora); `scripts/run_lint.py` is the CLI with
the baseline workflow.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional

from repro.analysis import (jit_hygiene, locks, pallas_contracts,
                            pytrees)
from repro.analysis.callgraph import Index
from repro.analysis.findings import Finding

CHECKERS = (jit_hygiene, locks, pallas_contracts, pytrees)


def run_lint(root, files: Optional[List[Path]] = None,
             checkers=CHECKERS) -> List[Finding]:
    """Analyze every .py file under `root` (or just `files`, which must
    live under it) and return sorted findings."""
    index = Index.build(root, files=files)
    findings: List[Finding] = []
    for checker in checkers:
        findings.extend(checker.check(index))
    findings.sort(key=lambda f: (f.file, f.line, f.col, f.code))
    return findings
