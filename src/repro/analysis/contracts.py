"""Concurrency contracts: annotations + the REPRO_TSAN runtime shim.

The serving stack shares mutable state across threads under a lock
discipline that PR 5 left implicit ("mutations of `_idle` happen with
`self._cond` held" was true only by convention).  This module makes the
convention explicit and checkable twice over:

**Statically** — the decorators below are metadata-only at runtime (they
stash the contract on the class/function and return it unchanged); the
`analysis.locks` checker reads them from the AST and verifies every
mutation site of a declared field is either inside a ``with self.<lock>:``
block, in a method declared `@runs_on(<owner>)` for an `owned_by` field,
or explicitly waived with `@exempt`.

**Dynamically** — under ``REPRO_TSAN=1``, `ThreadedExecutor` wraps its
Condition in a `CheckedCondition` (tracks the holding thread through
acquire/release/wait) and its annotated mutable fields in guarded
containers whose mutating methods assert the discipline on every call,
so the tier-1 suite doubles as a thread sanitizer for exactly the
annotated state.

Vocabulary:

  @locked_by("_cond", "_idle", "_errors")     # class decorator: every
      mutation of the named fields must hold ``self._cond``
  @owned_by("worker", "queue", "done")        # class decorator: the
      named fields are mutated only by the declared owner role (or
      under the class's declared lock, which also serializes)
  @runs_on("worker")                          # method decorator: this
      method executes in the named role's thread
  @exempt("queue", reason="...")              # method decorator: waive
      the static check for the named fields in this method; the reason
      is mandatory and the dynamic shim still covers the site

Owner names are roles, not thread ids — "worker" is whichever thread
drives the engine (a `ThreadedExecutor` worker, or the caller's thread
for a bare engine), "router" is the thread calling `Router.run()`.  The
runtime shim resolves roles to live threads at claim time
(`GuardedDeque.set_owner`).
"""
from __future__ import annotations

import collections
import os
import threading
from typing import Optional

__all__ = [
    "locked_by", "owned_by", "runs_on", "exempt", "tsan_enabled",
    "TsanViolation", "CheckedCondition", "GuardedList", "GuardedDict",
    "GuardedDeque",
]

CONTRACT_ATTR = "__repro_contracts__"


def _add_contract(obj, kind: str, payload: dict):
    table = getattr(obj, CONTRACT_ATTR, None)
    if table is None:
        table = []
        setattr(obj, CONTRACT_ATTR, table)
    table.append({"kind": kind, **payload})
    return obj


def locked_by(lock: str, *fields: str):
    """Class decorator: mutations of `fields` must hold ``self.<lock>``."""
    if not fields:
        raise TypeError("locked_by needs at least one field name")

    def deco(cls):
        return _add_contract(cls, "locked_by",
                             {"lock": lock, "fields": fields})
    return deco


def owned_by(owner: str, *fields: str):
    """Class decorator: `fields` are mutated only by the `owner` role
    (methods marked ``@runs_on(owner)``) or under the class's lock."""
    if not fields:
        raise TypeError("owned_by needs at least one field name")

    def deco(cls):
        return _add_contract(cls, "owned_by",
                             {"owner": owner, "fields": fields})
    return deco


def runs_on(owner: str):
    """Method decorator: the body executes in the `owner` role's thread."""

    def deco(fn):
        return _add_contract(fn, "runs_on", {"owner": owner})
    return deco


def exempt(*fields: str, reason: str):
    """Method decorator: waive the static lock/owner check for `fields`
    inside this method.  `reason` is mandatory — waivers are part of the
    reviewed contract, not an escape hatch (docs/analysis.md)."""
    if not fields:
        raise TypeError("exempt needs at least one field name")
    if not reason:
        raise TypeError("exempt needs a non-empty reason")

    def deco(fn):
        return _add_contract(fn, "exempt",
                             {"fields": fields, "reason": reason})
    return deco


# ---------------------------------------------------------------------------
# runtime sanitizer (REPRO_TSAN=1)
# ---------------------------------------------------------------------------

def tsan_enabled() -> bool:
    """True when the dynamic lock-discipline shim should be active.
    Read at object construction time (like REPRO_INTERPRET at trace
    time): flipping the env var after an executor exists has no effect
    on it."""
    return os.environ.get("REPRO_TSAN", "") not in ("", "0")


class TsanViolation(RuntimeError):
    """A guarded mutation ran without the declared lock/owner."""


class CheckedCondition:
    """A `threading.Condition` (over an RLock) that knows who holds it.

    Drop-in for the executor's ``_cond``: supports the context-manager
    protocol, `wait`, `notify`, `notify_all`, and adds
    `held_by_current()` — the predicate the guarded containers assert.
    Holder tracking survives `wait()` (which releases and reacquires)
    and re-entrant acquisition.
    """

    def __init__(self):
        self._cond = threading.Condition(threading.RLock())
        self._holder: Optional[threading.Thread] = None
        self._depth = 0

    # -- holder bookkeeping --------------------------------------------------

    def _acquired(self):
        self._holder = threading.current_thread()
        self._depth += 1

    def _releasing(self):
        self._depth -= 1
        if self._depth == 0:
            self._holder = None

    def held_by_current(self) -> bool:
        return self._holder is threading.current_thread()

    # -- condition protocol --------------------------------------------------

    def acquire(self, *a, **kw):
        got = self._cond.acquire(*a, **kw)
        if got:
            self._acquired()
        return got

    def release(self):
        self._releasing()
        self._cond.release()

    def __enter__(self):
        self._cond.__enter__()
        self._acquired()
        return self

    def __exit__(self, *exc):
        self._releasing()
        return self._cond.__exit__(*exc)

    def wait(self, timeout: Optional[float] = None):
        if not self.held_by_current():
            raise TsanViolation("wait() without holding the condition")
        # wait releases the lock fully, then reacquires at our depth
        depth, self._depth, self._holder = self._depth, 0, None
        try:
            return self._cond.wait(timeout)
        finally:
            self._depth, self._holder = depth, threading.current_thread()

    def notify(self, n: int = 1):
        self._cond.notify(n)

    def notify_all(self):
        self._cond.notify_all()


class _Guard:
    """Shared discipline check for the guarded containers.

    A mutation is legal when the guarding condition is held by the
    current thread, when the current thread is the registered owner, or
    when no owner is registered (the structure is quiescent — e.g. an
    engine between drives, warmed and read by the main thread).

    No __slots__: a mixin with slots cannot share an instance layout
    with the C container bases (list/dict/deque)."""

    def _init_guard(self, cond, label: str):
        self._tsan_cond = cond
        self._tsan_owner: Optional[threading.Thread] = None
        self._tsan_label = label

    def set_owner(self, thread: Optional[threading.Thread]):
        """Claim (or release, with None) exclusive mutation rights."""
        self._tsan_owner = thread

    def _check(self):
        if self._tsan_cond is not None and self._tsan_cond.held_by_current():
            return
        owner = self._tsan_owner
        if owner is None or owner is threading.current_thread():
            return
        raise TsanViolation(
            f"REPRO_TSAN: mutation of {self._tsan_label} on thread "
            f"{threading.current_thread().name!r} without holding the "
            f"guarding condition (owner: {owner.name!r})")


def _guarded(base, mutators):
    """Build a guarded subclass of `base` asserting before `mutators`."""

    def make(name):
        def method(self, *a, **kw):
            self._check()
            return getattr(base, name)(self, *a, **kw)
        method.__name__ = name
        return method

    ns = {name: make(name) for name in mutators}

    def __init__(self, data=(), *, cond=None, label="<guarded>"):
        base.__init__(self, data)
        self._init_guard(cond, label)
    ns["__init__"] = __init__
    return type(f"Guarded{base.__name__.capitalize()}", (base, _Guard), ns)


GuardedList = _guarded(list, (
    "__setitem__", "__delitem__", "__iadd__", "append", "extend",
    "insert", "pop", "remove", "clear", "sort", "reverse"))
GuardedDict = _guarded(dict, (
    "__setitem__", "__delitem__", "pop", "popitem", "clear", "update",
    "setdefault"))
GuardedDeque = _guarded(collections.deque, (
    "append", "appendleft", "extend", "extendleft", "pop", "popleft",
    "remove", "clear", "__setitem__", "__delitem__", "__iadd__"))
