"""Checker 2: lock discipline / thread-ownership of annotated fields.

Reads the `analysis.contracts` decorators off class definitions:

    @locked_by("_cond", "_idle", "_errors")
    @owned_by("router", "_threads")
    class ThreadedExecutor: ...

and verifies, for every method in the class body, that every mutation of
a declared field —

  * direct rebinding         ``self._idle = [...]``
  * element assignment       ``self._idle[i] = True``
  * augmented assignment     ``self.busy_seconds[i] += dt``
  * mutating method call     ``self._errors.append(e)``

— is (a) lexically inside ``with self.<lock>:`` for a `locked_by` field
(or for an `owned_by` field, since the lock also serializes), (b) inside
a method declared ``@runs_on(<owner>)`` matching the field's `owned_by`
owner, (c) inside ``__init__`` (construction happens-before publication),
or (d) explicitly waived with ``@exempt(field, reason=...)``.

Codes:

  LCK201  locked_by field mutated without the lock held
  LCK202  owned_by field mutated outside the owner's methods / the lock

Scope: mutations through `self` inside the declaring class body.
Mutations from outside the class (or through an alias) are the runtime
shim's job (REPRO_TSAN=1 guarded containers — see contracts.py).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.callgraph import Index, dotted
from repro.analysis.findings import Finding

CHECKER = "locks"

MUTATORS = {
    "append", "appendleft", "extend", "extendleft", "insert", "pop",
    "popleft", "popitem", "remove", "clear", "update", "setdefault",
    "add", "discard", "sort", "reverse",
}


@dataclass
class ClassContract:
    lock: Optional[str] = None
    locked_fields: Tuple[str, ...] = ()
    owners: Dict[str, str] = field(default_factory=dict)  # field -> owner


def _const_strs(call: ast.Call) -> List[str]:
    return [a.value for a in call.args
            if isinstance(a, ast.Constant) and isinstance(a.value, str)]


def _class_contract(cls: ast.ClassDef) -> Optional[ClassContract]:
    contract = ClassContract()
    found = False
    for deco in cls.decorator_list:
        if not isinstance(deco, ast.Call):
            continue
        name = (dotted(deco.func) or "").split(".")[-1]
        strs = _const_strs(deco)
        if name == "locked_by" and len(strs) >= 2:
            contract.lock = strs[0]
            contract.locked_fields += tuple(strs[1:])
            found = True
        elif name == "owned_by" and len(strs) >= 2:
            for f in strs[1:]:
                contract.owners[f] = strs[0]
            found = True
    return contract if found else None


def _method_markers(fn) -> Tuple[Optional[str], Dict[str, str]]:
    """(runs_on owner, {field: exempt reason}) from method decorators."""
    owner = None
    waived: Dict[str, str] = {}
    for deco in getattr(fn, "decorator_list", []):
        if not isinstance(deco, ast.Call):
            continue
        name = (dotted(deco.func) or "").split(".")[-1]
        if name == "runs_on":
            strs = _const_strs(deco)
            if strs:
                owner = strs[0]
        elif name == "exempt":
            reason = ""
            for kw in deco.keywords:
                if kw.arg == "reason" and isinstance(kw.value, ast.Constant):
                    reason = str(kw.value.value)
            for f in _const_strs(deco):
                waived[f] = reason
    return owner, waived


def _self_field(node) -> Optional[str]:
    """The field name when `node` is self.<field>, else None."""
    if isinstance(node, ast.Attribute) \
            and isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _mutation_sites(fn):
    """Yield (node, field, verb) for mutations of self.<field> inside
    `fn`, tracking whether each site is under `with self.<lock>`."""

    def visit(body, locks_held):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested defs are indexed as their own functions and
                # analyzed separately (they run later, possibly on
                # another thread — the lexical lock does not carry over)
                continue
            if isinstance(stmt, ast.With):
                held = set(locks_held)
                for item in stmt.items:
                    f = _self_field(item.context_expr)
                    if f is not None:
                        held.add(f)
                yield from visit(stmt.body, frozenset(held))
                continue
            yield from scan(stmt, locks_held)
            for attr in ("body", "orelse", "finalbody"):
                yield from visit(getattr(stmt, attr, []), locks_held)
            for h in getattr(stmt, "handlers", []):
                yield from visit(h.body, locks_held)

    def scan(stmt, locks_held):
        if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for tgt in targets:
                yield from target_sites(tgt, locks_held)
        elif isinstance(stmt, ast.AugAssign):
            yield from target_sites(stmt.target, locks_held)
        elif isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                yield from target_sites(tgt, locks_held)
        # mutating method calls in this statement's OWN expressions; for
        # compound statements only the header — nested statements are
        # scanned by visit()'s recursion (walking the whole subtree here
        # would re-report sites that sit under an inner `with self._lock`)
        if isinstance(stmt, (ast.If, ast.While, ast.For, ast.Try)):
            exprs = [e for e in (getattr(stmt, "test", None),
                                 getattr(stmt, "iter", None)) if e is not None]
        else:
            exprs = [stmt]
        for expr in exprs:
            for node in ast.walk(expr):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in MUTATORS:
                    f = _self_field(node.func.value)
                    if f is not None:
                        yield node, f, f".{node.func.attr}()", locks_held

    def target_sites(tgt, locks_held):
        f = _self_field(tgt)
        if f is not None:
            yield tgt, f, "assignment", locks_held
            return
        if isinstance(tgt, ast.Subscript):
            f = _self_field(tgt.value)
            if f is not None:
                yield tgt, f, "element assignment", locks_held
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                yield from target_sites(e, locks_held)

    yield from visit(fn.body, frozenset())


def check(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mi in index.modules.values():
        for cls_name, cls in mi.classes.items():
            contract = _class_contract(cls)
            if contract is None:
                continue
            declared = set(contract.locked_fields) | set(contract.owners)
            for fi in mi.functions.values():
                if fi.class_name != cls_name:
                    continue
                fn = fi.node
                if fi.local == f"{cls_name}.__init__":
                    continue   # construction happens-before publication
                owner, waived = _method_markers(fn)
                for node, fld, verb, locks in _mutation_sites(fn):
                    if fld not in declared:
                        continue
                    if fld in waived:
                        continue
                    if contract.lock is not None and contract.lock in locks:
                        continue
                    fld_owner = contract.owners.get(fld)
                    if fld_owner is not None and owner == fld_owner:
                        continue
                    if fld_owner is None:
                        findings.append(Finding(
                            file=mi.relpath, line=node.lineno,
                            col=node.col_offset, code="LCK201",
                            checker=CHECKER,
                            message=(f"{verb} of self.{fld} without "
                                     f"holding self.{contract.lock} "
                                     f"(locked_by contract)"),
                            context=fi.qualname))
                    else:
                        findings.append(Finding(
                            file=mi.relpath, line=node.lineno,
                            col=node.col_offset, code="LCK202",
                            checker=CHECKER,
                            message=(f"{verb} of self.{fld} outside its "
                                     f"owner {fld_owner!r} (owned_by "
                                     f"contract; mark the method "
                                     f"@runs_on({fld_owner!r}) or hold "
                                     f"the lock)"),
                            context=fi.qualname))
    return findings
