"""Checker 3: Pallas kernel contracts.

Two rules the kernel layer (kernels/*.py) lives by:

  PAL301  `REPRO_INTERPRET` is read outside `repro/kernels/ops.py` —
          interpret-mode policy has exactly one reader,
          `ops._interpret()`; raw env reads elsewhere fork the policy
          (and miss the documented trace-time semantics).
  PAL302  a `pl.pallas_call` grid expression calls into `jnp`/`jax`/
          `lax` or `.item()` — grids live on the HOST and must be
          shape-static ints (shapes, constants, `np`/`math` arithmetic),
          never traced values.
  PAL303  a BlockSpec index_map calls into host `np.*` or `.item()` —
          index maps are TRACED per grid step, so traced ops (`jnp`,
          clamps like `jnp.minimum` over scalar-prefetch refs) are fine
          but host numpy / syncs are not.
  PAL304  a `pl.pallas_call` outside `kernels/` hardcodes `interpret=`
          to a constant — interpret-mode policy flows from
          `kernels.ops._interpret()` (PAL301's single reader) down
          through the `kernels/*.py` wrappers as a parameter; a literal
          `interpret=True/False` elsewhere pins a kernel to one backend
          and silently ignores `REPRO_INTERPRET`.  Kernel modules may
          default the kwarg (`interpret: bool = False` threads fine);
          call sites everywhere else must pass a variable.

The single allowed reader is identified by file path suffix
(`repro/kernels/ops.py`), and PAL304's kernel layer by a `kernels/`
path component, so the rules hold verbatim when the tree is analyzed
from a checkout root or a fixture corpus.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.callgraph import Index, dotted
from repro.analysis.findings import Finding

CHECKER = "pallas_contracts"

ALLOWED_ENV_READER = "repro/kernels/ops.py"
_TRACED_PREFIXES = ("jnp", "jax", "lax")   # banned where host-static
_HOST_PREFIXES = ("np", "numpy")           # banned where traced


def _reads_repro_interpret(node: ast.AST) -> bool:
    """True for os.environ.get("REPRO_INTERPRET"), os.getenv(...), and
    os.environ["REPRO_INTERPRET"]."""
    if isinstance(node, ast.Call):
        name = dotted(node.func) or ""
        if name in ("os.environ.get", "os.getenv", "environ.get",
                    "getenv"):
            return any(isinstance(a, ast.Constant)
                       and a.value == "REPRO_INTERPRET"
                       for a in node.args)
    if isinstance(node, ast.Subscript):
        name = dotted(node.value) or ""
        if name in ("os.environ", "environ"):
            sl = node.slice
            return isinstance(sl, ast.Constant) \
                and sl.value == "REPRO_INTERPRET"
    return False


def _impure_call(expr: ast.AST, banned_prefixes):
    """First banned-prefix call or .item() inside `expr`, else None."""
    for node in ast.walk(expr):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            return node, ".item()"
        name = dotted(node.func) or ""
        if name.split(".")[0] in banned_prefixes:
            return node, f"{name}(...)"
    return None


def _in_kernels(relpath: str) -> bool:
    path = relpath.replace("\\", "/")
    return "kernels/" in path.rsplit("/", 1)[0] + "/"


def check(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mi in index.modules.values():
        is_ops = mi.relpath.replace("\\", "/").endswith(ALLOWED_ENV_READER)
        for node in ast.walk(mi.tree):
            if not is_ops and _reads_repro_interpret(node):
                findings.append(Finding(
                    file=mi.relpath, line=node.lineno,
                    col=node.col_offset, code="PAL301", checker=CHECKER,
                    message=("raw REPRO_INTERPRET read; interpret-mode "
                             "policy is read only via "
                             "kernels.ops._interpret()")))
            if isinstance(node, ast.Call):
                callee = (dotted(node.func) or "").split(".")[-1]
                if callee == "pallas_call":
                    findings.extend(_check_pallas_call(mi, node))
                    if not _in_kernels(mi.relpath):
                        findings.extend(_check_interpret_literal(mi, node))
                elif callee == "BlockSpec":
                    findings.extend(_check_blockspec(mi, node))
    return findings


def _check_pallas_call(mi, call: ast.Call) -> List[Finding]:
    out: List[Finding] = []
    for kw in call.keywords:
        if kw.arg != "grid":
            continue
        hit = _impure_call(kw.value, _TRACED_PREFIXES)
        if hit is not None:
            node, what = hit
            out.append(Finding(
                file=mi.relpath, line=node.lineno, col=node.col_offset,
                code="PAL302", checker=CHECKER,
                message=(f"pallas_call grid uses {what}: grids must be "
                         f"shape-static host integers, not traced "
                         f"values")))
    return out


def _check_interpret_literal(mi, call: ast.Call) -> List[Finding]:
    out: List[Finding] = []
    for kw in call.keywords:
        if kw.arg == "interpret" and isinstance(kw.value, ast.Constant):
            out.append(Finding(
                file=mi.relpath, line=kw.value.lineno,
                col=kw.value.col_offset, code="PAL304", checker=CHECKER,
                message=(f"pallas_call outside kernels/ hardcodes "
                         f"interpret={kw.value.value!r}; interpret-mode "
                         f"policy flows from kernels.ops._interpret() — "
                         f"thread it as a variable")))
    return out


def _check_blockspec(mi, call: ast.Call) -> List[Finding]:
    out: List[Finding] = []
    candidates = []
    if len(call.args) >= 2:
        candidates.append(call.args[1])
    for kw in call.keywords:
        if kw.arg == "index_map":
            candidates.append(kw.value)
    for expr in candidates:
        body = expr.body if isinstance(expr, ast.Lambda) else expr
        hit = _impure_call(body, _HOST_PREFIXES)
        if hit is not None:
            node, what = hit
            out.append(Finding(
                file=mi.relpath, line=node.lineno, col=node.col_offset,
                code="PAL303", checker=CHECKER,
                message=(f"BlockSpec index_map uses {what}: index maps "
                         f"are traced — host numpy / syncs are illegal "
                         f"there")))
    return out
