"""Sharded checkpoint manager: atomic, manifest-verified, async-capable.

Layout:
    <dir>/step_<N>/arrays.npz      flattened pytree leaves
    <dir>/step_<N>/manifest.json   tree structure + shapes + dtypes + meta
    <dir>/LATEST                   text file with the newest complete step

Write protocol (crash-safe): write into step_<N>.tmp/, fsync, rename to
step_<N>/, then update LATEST.  A half-written checkpoint can never be
picked up by restore() because the rename is atomic and LATEST only moves
after the rename.  `keep` bounds retention.  save_async overlaps the host
write with the next training step (device->host transfer happens before
the thread starts so the arrays are immutable snapshots).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np

import jax

SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- write ----
    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        flat = _flatten(tree)
        self._write(step, flat, meta or {})

    def save_async(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        flat = _flatten(tree)   # snapshot on host before returning
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, meta or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "meta": meta,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "LATEST"), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---- read ----
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                s = int(f.read().strip())
            if s in self.steps():
                return s
        steps = self.steps()     # LATEST missing/stale: trust the manifests
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None):
        """Restore into the structure of `template` (shapes verified).
        Returns (tree, step, meta) or (None, None, None) if empty."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in paths:
            key = SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            if key not in data:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"template {leaf.shape}")
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, step, manifest.get("meta", {})
