"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh for smoke tests."""
    return make_mesh((1, 1), ("data", "model"))


def make_elastic_mesh(n_devices: int, model: int = 16):
    """Degraded-fleet mesh: keep the model axis intact, shrink data.
    Used by the elastic-scaling path (runtime/elastic.py) after node loss."""
    data = n_devices // model
    if data < 1:
        raise ValueError(f"need >= {model} devices, have {n_devices}")
    devs = jax.devices()[: data * model]
    return make_mesh((data, model), ("data", "model"), devices=devs)
