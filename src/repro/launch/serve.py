"""Batched serving driver: prefill + decode with DSG active at inference.

The paper extends DSG to inference by keeping the on-the-fly
dimension-reduction search (Appendix C: stored per-sample masks would cost
more memory than they save, so the search stays online).  Two workloads:

  * --workload batch (default): one fixed-shape batch — batched prompt
    prefill -> KV cache -> token-by-token decode, same DSG masks in both
    phases.
  * --workload mixed: continuous batching over mixed-length synthetic
    traffic through the overlap-admission ServingEngine (prompts and
    generation budgets drawn per request; per-slot admission/retirement).
    --cache-backend picks the KV-cache layout (dense worst-case or paged
    with --page-size/--cache-tokens; see serving/kv_cache.py),
    --paged-kernel picks the paged decode executor (Pallas
    kernels/paged_attention.py vs bounded XLA gather), and
    --temperature/--top-p enable in-step nucleus sampling.
    --replicas N runs the traffic through the front-end router
    (serving/router.py) over N per-replica engines with --route-policy
    round_robin / least_queue / least_pages, and --exec-mode picks the
    replica executor (serving/parallel_exec.py): sequential in-process
    stepping reports the MODELED data-parallel makespan (slowest
    replica's busy time), threaded / sharded run the replica group in
    true parallel and report the MEASURED makespan.
    --fault-tolerance opts the router into failure containment
    (docs/fault_tolerance.md: health states, failover, retry budgets;
    tune with --max-replica-restarts/--max-retries/--deadline-s/
    --stall-timeout-s) and --chaos KIND@REPLICA:STEP injects
    deterministic faults (kill/delay/poison) to watch it work.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --smoke --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --smoke --workload mixed --requests 16 --slots 4 --admission overlap
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api
from repro.parallel import context as pctx


def generate(cfg, params, dsg, prompts: jax.Array, gen_tokens: int,
             *, mesh=None, temperature: float = 0.0, seed: int = 0):
    """prompts (B, P) int32 -> generated (B, gen_tokens).  Greedy or
    temperature sampling; decode step is jitted once and reused."""
    b, p_len = prompts.shape
    max_seq = p_len + gen_tokens
    cache = api.make_cache(cfg, b, max_seq)

    with pctx.use_mesh(mesh):
        prefill = jax.jit(lambda pr, dg, inp, c: api.prefill(
            pr, dg, cfg, inp, c))
        decode = jax.jit(lambda pr, dg, tok, st, pos: api.decode_step(
            pr, dg, cfg, tok, st, pos))

        logits, state = prefill(params, dsg, {"tokens": prompts}, cache)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = None
        for i in range(gen_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(tok)
            logits, state = decode(params, dsg, tok[:, None].astype(jnp.int32),
                                   state, jnp.int32(p_len + i))
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workload", choices=("batch", "mixed"),
                    default="batch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--no-dsg", action="store_true")
    ap.add_argument("--gamma", type=float, default=None,
                    help="DSG sparsity: fraction of neuron groups dropped "
                         "(DSGConfig.gamma, in [0, 1); default: the "
                         "arch config's value)")
    ap.add_argument("--dsg-threshold-mode",
                    choices=("topk", "shared", "ema"), default=None,
                    help="DRS threshold mode (DSGConfig.threshold_mode): "
                         "per-row topk, the paper's inter-sample shared "
                         "threshold, or a cross-step EMA")
    ap.add_argument("--dsg-serving", action="store_true",
                    help="mixed workload: serving-side DSG sparsity "
                         "runtime (serving/dsg_runtime.py) — per-lane "
                         "group-CSR patterns drive a sparse FFN decode, "
                         "refreshed every --dsg-refresh-interval tokens")
    ap.add_argument("--dsg-refresh-interval", type=int, default=8,
                    help="emitted tokens between DRS pattern refreshes "
                         "per lane (--dsg-serving)")
    ap.add_argument("--dsg-apply",
                    choices=("auto", "dense", "xla", "kernel"),
                    default="auto",
                    help="group-CSR FFN executor for --dsg-serving "
                         "(ModelConfig.dsg_ffn_apply): masked-dense "
                         "reference, bounded XLA gather, Pallas CSR "
                         "kernel, or auto (kernel on TPU)")
    # mixed-workload knobs
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=384)
    ap.add_argument("--prompt-bucket", type=int, default=256)
    ap.add_argument("--admission", choices=("overlap", "wave"),
                    default="overlap")
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="decode steps fused into one device dispatch "
                         "(scheduler.make_chunked_decode_fns): EOS / "
                         "budget freezing stays on device and the host "
                         "syncs once per chunk instead of per token; "
                         "temperature-0 streams are bitwise-identical "
                         "to --decode-chunk 1 "
                         "(benchmarks/bench_decode_loop.py gates the "
                         "speedup)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel serving replicas behind the "
                         "front-end router (serving/router.py)")
    ap.add_argument("--route-policy",
                    choices=("round_robin", "least_queue", "least_pages"),
                    default="least_queue",
                    help="replica routing policy when --replicas > 1")
    ap.add_argument("--exec-mode",
                    choices=("sequential", "threaded", "sharded"),
                    default="sequential",
                    help="replica executor (serving/parallel_exec.py): "
                         "sequential in-process stepping (modeled "
                         "makespan), threaded worker per replica, or one "
                         "vmapped step over the stacked replica group "
                         "(both: measured makespan)")
    ap.add_argument("--cache-backend", choices=("dense", "paged"),
                    default="dense",
                    help="KV-cache layout (serving/kv_cache.py)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per page for --cache-backend paged")
    ap.add_argument("--cache-tokens", type=int, default=None,
                    help="paged pool capacity in tokens "
                         "(default: slots * max-seq, the dense worst case)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="dedupe identical prompt prefixes onto shared "
                         "refcounted pages with copy-on-write "
                         "(--cache-backend paged only; "
                         "docs/cache_backends.md)")
    ap.add_argument("--paged-kernel", choices=("auto", "kernel", "xla"),
                    default="auto",
                    help="paged decode executor: Pallas kernel "
                         "(kernels/paged_attention.py, interpret on CPU), "
                         "bounded XLA gather, or auto (kernel on TPU)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass kept when sampling")
    # fault tolerance + chaos (docs/fault_tolerance.md)
    ap.add_argument("--chaos", action="append", default=[],
                    metavar="KIND@REPLICA:STEP[:SECONDS]",
                    help="mixed workload: inject a deterministic fault "
                         "(runtime/fault_tolerance.py) — kill@1:40 "
                         "raises on replica 1 at engine step 40, "
                         "delay@0:10:0.05 sleeps 0.05s, poison@2:9 "
                         "corrupts resident outputs then raises; "
                         "repeatable; implies --fault-tolerance")
    ap.add_argument("--fault-tolerance", action="store_true",
                    help="opt the router into failure containment "
                         "(serving/router.py FaultToleranceConfig); "
                         "without it a replica failure crashes the run")
    ap.add_argument("--max-replica-restarts", type=int, default=1,
                    help="restarts before a failed replica is marked "
                         "DEAD for good")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="per-request re-dispatch budget after replica "
                         "failures; beyond it the request fails")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (submit->finish); expired "
                         "queued requests finish with status timed_out")
    ap.add_argument("--stall-timeout-s", type=float, default=None,
                    help="threaded executor: seconds without step "
                         "progress before a replica is marked SUSPECT "
                         "and aborted")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.no_dsg:
        cfg = cfg.replace(dsg=cfg.dsg._replace(enabled=False))
    if args.gamma is not None:
        if not 0.0 <= args.gamma < 1.0:
            ap.error(f"--gamma must be in [0, 1), got {args.gamma}")
        cfg = cfg.replace(dsg=cfg.dsg._replace(gamma=args.gamma))
    if args.dsg_threshold_mode is not None:
        cfg = cfg.replace(dsg=cfg.dsg._replace(
            threshold_mode=args.dsg_threshold_mode))
    if args.dsg_serving and args.no_dsg:
        ap.error("--dsg-serving needs DSG enabled (drop --no-dsg)")
    if args.dsg_serving and args.workload != "mixed":
        ap.error("--dsg-serving is a mixed-workload (serving engine) "
                 "feature; add --workload mixed")
    cfg = cfg.replace(paged_attn_kernel=args.paged_kernel,
                      dsg_ffn_apply=args.dsg_apply)
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    if (args.chaos or args.fault_tolerance) and args.workload != "mixed":
        ap.error("--chaos/--fault-tolerance drive the serving engine; "
                 "add --workload mixed")

    if args.workload == "mixed":
        from repro.runtime.fault_tolerance import ReplicaFault
        from repro.serving.dsg_runtime import DSGServingConfig
        from repro.serving.router import FaultToleranceConfig
        from repro.serving.workload import mixed_requests, run_workload

        def _parse_chaos(spec: str) -> ReplicaFault:
            # KIND@REPLICA:STEP[:SECONDS], e.g. kill@1:40, delay@0:10:0.05
            try:
                kind, _, rest = spec.partition("@")
                replica, step, *extra = rest.split(":")
                return ReplicaFault(replica=int(replica), step=int(step),
                                    kind=kind,
                                    delay_s=(float(extra[0]) if extra
                                             else 0.05))
            except ValueError as e:
                ap.error(f"bad --chaos spec {spec!r} "
                         f"(KIND@REPLICA:STEP[:SECONDS]): {e}")

        faults = [_parse_chaos(s) for s in args.chaos] or None
        ft = (FaultToleranceConfig(
            max_replica_restarts=args.max_replica_restarts,
            max_retries=args.max_retries,
            stall_timeout_s=args.stall_timeout_s)
            if (args.fault_tolerance or faults) else None)
        dsg_serving = (DSGServingConfig(
            refresh_interval=args.dsg_refresh_interval)
            if args.dsg_serving else None)
        reqs = mixed_requests(cfg.vocab, args.requests, seed=args.seed,
                              temperature=args.temperature,
                              top_p=args.top_p)
        if args.deadline_s is not None:
            for r in reqs:
                r.deadline_s = args.deadline_s
        stats = run_workload(cfg, params, dsg, reqs,
                             admission=args.admission, n_slots=args.slots,
                             max_seq=args.max_seq,
                             prompt_bucket=args.prompt_bucket,
                             cache_backend=args.cache_backend,
                             page_size=args.page_size,
                             cache_tokens=args.cache_tokens,
                             replicas=args.replicas,
                             route_policy=args.route_policy,
                             exec_mode=args.exec_mode,
                             dsg_serving=dsg_serving,
                             fault_tolerance=ft, faults=faults,
                             decode_chunk=args.decode_chunk,
                             prefix_sharing=args.prefix_sharing,
                             seed=args.seed)
        tag = f"{stats['admission']}/{stats['cache_backend']}"
        if stats.get("prefix_sharing"):
            tag += "/shared"
        if stats["decode_chunk"] > 1:
            tag += f"/chunk{stats['decode_chunk']}"
        if "route_policy" in stats:
            tag += (f"/{stats['replicas']}x {stats['route_policy']}"
                    f"/{stats['exec_mode']}")
        print(f"[{tag}] {stats['requests']} requests, "
              f"{stats['tokens']} tokens in {stats['wall_s']:.2f}s = "
              f"{stats['tok_per_s']:.1f} tok/s "
              f"(decode {stats['decode_tok_per_s']:.1f} tok/s); latency "
              f"p50 {stats['p50_s']:.2f}s p95 {stats['p95_s']:.2f}s "
              f"({stats['steps']} decode steps, "
              f"cache {stats['cache_bytes'] / 1e6:.2f} MB resident, "
              f"{stats['truncated']} truncated)")
        if "makespan_s" in stats:
            kind = ("measured" if stats["makespan_measured"]
                    else "modeled")
            print(f"  {kind} parallel makespan {stats['makespan_s']:.2f}s "
                  f"= {stats['parallel_tok_per_s']:.1f} tok/s across "
                  f"{stats['replicas']} replicas ({stats['exec_mode']})")
        if "replica_health" in stats:
            print(f"  fault tolerance: {stats['completed_ok']} ok, "
                  f"{stats['failed']} failed, {stats['timed_out']} timed "
                  f"out, {stats['retries']} retries, "
                  f"{stats['faults_fired']} fault(s) fired; replica "
                  f"health {stats['replica_health']}")
        if "shared_page_hits" in stats:
            print(f"  prefix sharing: {stats['shared_page_hits']} page "
                  f"hit(s), {stats['cow_copies']} COW cop(ies), "
                  f"{stats['prefill_cache_hits']} prefill replay(s), "
                  f"peak {stats['peak_live_pages']} live pages")
        return

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len),
                                       dtype=np.int32))
    t0 = time.perf_counter()
    toks = generate(cfg, params, dsg, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s); "
          f"first row: {np.asarray(toks[0])[:8]}")


if __name__ == "__main__":
    main()
