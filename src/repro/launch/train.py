"""End-to-end fault-tolerant training driver.

Composes everything: config -> model + DSG state -> sharded train step
(pjit) -> synthetic data -> AdamW(+ZeRO-1) -> f(W) refresh every
dsg.refresh_every steps (the paper's projection amortization) -> async
checkpoints -> straggler monitor -> crash/restore loop.

Runs at smoke scale on CPU (examples/quickstart.py) and, unchanged, on the
production mesh (launcher flags pick the mesh).

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.data import synthetic
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import api, specs
from repro.optim import adamw
from repro.optim.compress import init_feedback, tree_compress_with_feedback
from repro.parallel import context as pctx
from repro.parallel.sharding import axes_for_mesh, model_shards
from repro.runtime.fault_tolerance import StragglerMonitor, run_with_restarts

log = logging.getLogger("repro.train")


def build_trainer(cfg, mesh, acfg: adamw.AdamWConfig, *,
                  grad_compress: bool = False, seed: int = 0):
    """Returns (state, step_fn, refresh_fn, state_shardings)."""
    ax = axes_for_mesh(mesh)
    n_model = model_shards(mesh)
    key = jax.random.PRNGKey(seed)

    with pctx.use_mesh(mesh):
        params = api.init_model(key, cfg)
        dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)
        use_master = cfg.dtype == "bfloat16"
        opt = adamw.init_opt(params, use_master)

        pspecs = specs.param_specs(params, cfg, ax, n_model)
        dspecs = specs.dsg_specs(dsg, cfg, ax, n_model)
        ospecs = (adamw.opt_specs_with_master(pspecs, params)
                  if use_master else adamw.opt_specs(pspecs, params))
        state = {"params": params, "dsg": dsg, "opt": opt}
        sspecs = {"params": pspecs, "dsg": dspecs, "opt": ospecs}
        if grad_compress:
            state["err"] = init_feedback(params)
            sspecs["err"] = pspecs
        if mesh.size > 1:
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                              is_leaf=lambda x: isinstance(x, P))
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, sh)

        batch_axes = ax.batch

        def step_fn(state, batch):
            def loss_fn(p):
                return api.train_loss(p, state["dsg"], cfg, batch,
                                      mesh=mesh, batch_axes=batch_axes)
            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            new_state = dict(state)
            if grad_compress:
                # ternary + error feedback on the gradient stream
                grads, new_state["err"] = tree_compress_with_feedback(
                    grads, state["err"])
            new_p, new_opt, metrics = adamw.apply_updates(
                state["params"], grads, state["opt"], acfg)
            metrics["loss"] = loss
            new_state.update(params=new_p, opt=new_opt)
            return new_state, metrics

        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        def refresh_fn(state):
            new_dsg = api.refresh_dsg(state["dsg"], state["params"], cfg)
            return {**state, "dsg": new_dsg}

        jit_refresh = jax.jit(refresh_fn, donate_argnums=(0,))

    return state, jit_step, jit_refresh, sspecs


def train(cfg, *, mesh=None, steps: int = 100, ckpt_dir=None,
          ckpt_every: int = 20, grad_compress: bool = False,
          global_batch: int = 8, seq_len: int = 64, seed: int = 0,
          injector=None, log_every: int = 10):
    mesh = mesh or make_local_mesh()
    acfg = adamw.AdamWConfig(total_steps=steps, warmup=min(20, steps // 5 + 1))
    state, jit_step, jit_refresh, _ = build_trainer(
        cfg, mesh, acfg, grad_compress=grad_compress, seed=seed)

    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt is not None:
        restored, rstep, _ = ckpt.restore(state)
        if restored is not None:
            state, start = restored, rstep
            log.info("resumed from step %d", start)

    def make_batch(step):
        return synthetic.batch_at(step, global_batch=global_batch,
                                  seq_len=seq_len, vocab=cfg.vocab,
                                  seed=seed)

    monitor = StragglerMonitor()
    refresh_every = max(1, cfg.dsg.refresh_every)

    def step_with_refresh(state, batch):
        new_state, metrics = jit_step(state, batch)
        step = int(new_state["opt"]["step"])
        if cfg.dsg.enabled and step % refresh_every == 0:
            new_state = jit_refresh(new_state)   # paper: every 50 steps
        return new_state, metrics

    state, history = run_with_restarts(
        step_fn=step_with_refresh, state=state, make_batch=make_batch,
        ckpt=ckpt, total_steps=steps, start_step=start,
        ckpt_every=ckpt_every, injector=injector, monitor=monitor,
        on_step=(lambda s, st, m: log.info(
            "step %d loss %.4f", s, float(m["loss"]))
            if s % log_every == 0 else None))
    return state, history, monitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_production_mesh() if args.production_mesh else None
    t0 = time.perf_counter()
    _, history, monitor = train(cfg, mesh=mesh, steps=args.steps,
                                ckpt_dir=args.ckpt_dir,
                                grad_compress=args.grad_compress,
                                global_batch=args.batch, seq_len=args.seq)
    losses = [h["loss"] for h in history]
    print(f"steps={len(history)} first_loss={losses[0]:.4f} "
          f"last_loss={losses[-1]:.4f} wall={time.perf_counter()-t0:.1f}s "
          f"stragglers={len(monitor.flagged)}")


if __name__ == "__main__":
    main()
