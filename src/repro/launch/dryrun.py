import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell: build the step function
(train_step with full AdamW update, or serve prefill/decode), attach
in/out shardings from the spec rules, .lower().compile() against the
production mesh, and record:
  * memory_analysis()  — bytes per device (proves it fits),
  * cost_analysis()    — per-device HLO FLOPs / bytes,
  * collective bytes   — parsed from the compiled HLO text,
into a JSON file consumed by the roofline analysis (benchmarks/roofline.py).

NOTE: the XLA_FLAGS line above MUST run before any other import touches
jax — 512 host platform devices stand in for the 2x16x16 v5e fleet.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
      --shape train_4k --mesh single --out results/
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import shape_by_name, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models import api, specs
from repro.optim import adamw
from repro.parallel import context as pctx
from repro.parallel.sharding import Axes, axes_for_mesh, data_shards, model_shards

from repro.launch import hlo_analysis


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def apply_overrides(cfg, overrides):
    """--set key=value pairs onto ModelConfig (dotted 'dsg.*' reaches the
    DSGConfig).  Values are literal-eval'd with string fallback."""
    import ast
    for kv in overrides or ():
        key, val = kv.split("=", 1)
        try:
            val = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            pass
        if key.startswith("dsg."):
            cfg = cfg.replace(dsg=cfg.dsg._replace(**{key[4:]: val}))
        else:
            cfg = cfg.replace(**{key: val})
    return cfg


def build_cell(arch: str, shape_name: str, mesh, dsg_on: bool = True,
               remat: bool = True, overrides=None):
    """Returns (fn, example_args(SDS), in_shardings) for the cell."""
    cfg = configs.get_config(arch)
    if not dsg_on:
        cfg = cfg.replace(dsg=cfg.dsg._replace(enabled=False))
    if not remat:
        cfg = cfg.replace(remat=False)
    cfg = apply_overrides(cfg, overrides)
    shape = shape_by_name(shape_name)
    ax = axes_for_mesh(mesh)
    n_model = model_shards(mesh)
    n_data = data_shards(mesh)
    batch_ok = shape.global_batch % n_data == 0
    if not batch_ok:
        ax = Axes(batch=None, model=ax.model)

    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(lambda: api.init_model(key, cfg))
    dsg_sds = jax.eval_shape(lambda p: api.init_dsg(key, p, cfg),
                             params_sds) if cfg.dsg.enabled else None
    pspecs = specs.param_specs(params_sds, cfg, ax, n_model)
    dspecs = specs.dsg_specs(dsg_sds, cfg, ax, n_model)
    batch_axes = ax.batch

    if shape.kind == "train":
        batch_sds = api.make_inputs(cfg, shape)
        bspecs = specs.input_specs(batch_sds, cfg, ax)
        ospecs = adamw.opt_specs_with_master(pspecs, params_sds, zero1=True) \
            if cfg.dtype == "bfloat16" else \
            adamw.opt_specs(pspecs, params_sds, zero1=True)
        opt_sds = jax.eval_shape(
            lambda p: adamw.init_opt(p, cfg.dtype == "bfloat16"), params_sds)
        acfg = adamw.AdamWConfig()

        def train_step(state, batch):
            def loss_fn(p, b):
                return api.train_loss(p, state["dsg"], cfg, b,
                                      mesh=mesh, batch_axes=batch_axes)

            mb = max(1, cfg.microbatches)
            if mb == 1:
                loss, grads = jax.value_and_grad(loss_fn)(
                    state["params"], batch)
            else:
                # gradient accumulation: stash lives per microbatch
                split = jax.tree.map(
                    lambda t: t.reshape((mb, t.shape[0] // mb)
                                        + t.shape[1:]), batch)
                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])

                def mb_body(acc, b):
                    g_acc, l_acc = acc
                    loss, g = jax.value_and_grad(loss_fn)(
                        state["params"], b)
                    g_acc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + loss), None

                (grads, loss), _ = jax.lax.scan(
                    mb_body, (zero, jnp.float32(0.0)), split)
                grads = jax.tree.map(lambda g: g / mb, grads)
                loss = loss / mb
            new_p, new_opt, metrics = adamw.apply_updates(
                state["params"], grads, state["opt"], acfg)
            metrics["loss"] = loss
            return {"params": new_p, "dsg": state["dsg"],
                    "opt": new_opt}, metrics

        state_sds = {"params": params_sds, "dsg": dsg_sds, "opt": opt_sds}
        state_specs = {"params": pspecs, "dsg": dspecs, "opt": ospecs}
        fn = train_step
        args = (state_sds, batch_sds)
        in_sh = (named(mesh, state_specs), named(mesh, bspecs))
        out_sh = (named(mesh, state_specs), None)
        donate = (0,)
    elif shape.kind == "prefill":
        inputs_sds = api.make_inputs(cfg, shape)
        ispecs = specs.input_specs(inputs_sds, cfg, ax)
        cache_sds = jax.eval_shape(
            lambda: api.make_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = specs.cache_specs(cache_sds, cfg, ax, n_model)

        def prefill_fn(params, dsg, inputs, cache):
            return api.prefill(params, dsg, cfg, inputs, cache,
                               mesh=mesh, batch_axes=batch_axes)

        fn = prefill_fn
        args = (params_sds, dsg_sds, inputs_sds, cache_sds)
        in_sh = (named(mesh, pspecs), named(mesh, dspecs),
                 named(mesh, ispecs), named(mesh, cspecs))
        out_sh = None
        donate = (3,) if cache_sds is not None else ()
    else:  # decode
        inputs_sds = api.make_inputs(cfg, shape)
        cache_sds = jax.eval_shape(
            lambda: api.make_cache(cfg, shape.global_batch, shape.seq_len))
        prompt = api.make_inputs(
            cfg, shape_by_name(shape_name).__class__(
                name="p", seq_len=shape.seq_len, global_batch=shape.global_batch,
                kind="prefill"))
        state_sds = jax.eval_shape(
            lambda p, d, pr, c: api.prefill(p, d, cfg, pr, c),
            params_sds, dsg_sds, prompt, cache_sds)[1]
        sspecs = specs.cache_specs(state_sds, cfg, ax, n_model)

        def decode_fn(params, dsg, token, state, pos):
            return api.decode_step(params, dsg, cfg, token, state, pos,
                                   mesh=mesh, batch_axes=batch_axes)

        fn = decode_fn
        args = (params_sds, dsg_sds, inputs_sds["token"], state_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (named(mesh, pspecs), named(mesh, dspecs),
                 NamedSharding(mesh, P(ax.batch, None)),
                 named(mesh, sspecs), NamedSharding(mesh, P()))
        out_sh = None
        donate = (3,)
    return cfg, fn, args, in_sh, out_sh, donate, batch_ok


_HLO_DIR = None     # set by main() to persist compiled HLO next to JSONs


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             dsg_on: bool = True, remat: bool = True,
             overrides=None, tag: str = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi_pod" if multi_pod else "single_pod",
           "devices": mesh.size, "dsg": dsg_on,
           "overrides": list(overrides or ()), "tag": tag}
    if not configs.cell_is_runnable(arch, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §4)")
        return rec
    t0 = time.perf_counter()
    cfg, fn, args, in_sh, out_sh, donate, batch_ok = build_cell(
        arch, shape_name, mesh, dsg_on, remat, overrides)
    with pctx.use_mesh(mesh, batch_shardable=batch_ok):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    if mem is not None:
        rec["memory"] = {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")}
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    rec["cost_xla"] = {k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float)) and k in
                      ("flops", "bytes accessed", "transcendentals",
                       "bytes accessed output", "optimal_seconds")}
    hlo = compiled.as_text()
    # scan-aware accounting (cost_analysis counts while bodies once)
    rec["analysis"] = hlo_analysis.analyze(hlo)
    rec["hlo_lines"] = len(hlo.splitlines())
    if _HLO_DIR:
        import gzip
        ftag = (f"{arch}__{shape_name}__"
                f"{'multi_pod' if multi_pod else 'single_pod'}__"
                f"{tag or ('dsg' if dsg_on else 'dense')}")
        with gzip.open(os.path.join(_HLO_DIR, ftag + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-dsg", action="store_true")
    ap.add_argument("--set", nargs="*", default=None,
                    help="cfg overrides, e.g. dsg.mode=gather_shared")
    ap.add_argument("--tag", default=None,
                    help="variant tag for output filenames")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    global _HLO_DIR
    _HLO_DIR = os.path.join(args.out, "hlo")
    os.makedirs(_HLO_DIR, exist_ok=True)

    tag = "dsg" if not args.no_dsg else "dense"
    if args.all:
        # one subprocess per cell: isolates compiler memory and failures,
        # resumable (existing JSONs are skipped).
        import subprocess
        cells = [(arch, shape.name, mesh)
                 for arch in configs.ARCHS
                 for shape in SHAPES
                 for mesh in ("single", "multi")]
        for arch, shape, mesh in cells:
            fname = os.path.join(args.out,
                                 f"{arch}__{shape}__{mesh}__{tag}.json")
            if os.path.exists(fname):
                print(f"[skip existing] {fname}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", args.out] + (["--no-dsg"] if args.no_dsg else [])
            try:
                subprocess.run(cmd, timeout=3600)
            except subprocess.TimeoutExpired:
                with open(fname, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                               "status": "error",
                               "error": "compile timeout (3600s)"}, f)
                print(f"  -> TIMEOUT {arch} {shape} {mesh}", flush=True)
        return

    arch, shape, mesh = args.arch, args.shape, args.mesh
    tag = args.tag or tag
    fname = os.path.join(args.out, f"{arch}__{shape}__{mesh}__{tag}.json")
    if os.path.exists(fname):
        print(f"[skip existing] {fname}")
        return
    print(f"[dryrun] {arch} x {shape} x {mesh} ({tag}) ...", flush=True)
    try:
        rec = run_cell(arch, shape, mesh == "multi", dsg_on=not args.no_dsg,
                       overrides=args.set, tag=tag)
    except Exception as e:
        rec = {"arch": arch, "shape": shape, "mesh": mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"  -> {rec['status']}"
          + (f" compile={rec.get('compile_s')}s" if rec.get("compile_s")
             else "")
          + (f" err={rec.get('error', '')[:300]}"
             if rec["status"] == "error" else ""), flush=True)


if __name__ == "__main__":
    main()
