"""HLO-text analyzer: correct per-device FLOP / byte / collective accounting.

Why this exists: XLA's `compiled.cost_analysis()` counts a `while` body
ONCE, so any lax.scan-over-layers model under-reports FLOPs by ~n_layers,
and collectives inside the scanned layer are likewise dropped from naive
text scans.  This module parses the compiled (post-SPMD, per-device) HLO:

  * splits the module into computations,
  * computes dot FLOPs from operand/output shapes (2*prod(out)*prod(contract)),
  * sums collective payload bytes (result-shape convention),
  * estimates HBM traffic as sum(output+operand bytes) of top-level ops
    (fusion-internal ops excluded — they live in registers/VMEM),
  * resolves the call graph, multiplying `while` bodies by their
    backend_config known_trip_count (nested loops compose).

Known approximations (documented in EXPERIMENTS.md):
  * conditional branches are counted at max(branch) cost;
  * sort/top-k comparator FLOPs ignored (negligible);
  * HBM bytes are an upper-ish estimate (no cache reuse modeling).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.+?)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*{[\\"]*n[\\"]*:[\\"]*(\d+)')


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type str


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
                # parameters are declared in the signature; their shapes
                # also appear as "%x = T[...] parameter(n)" lines in-body.
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), line)
            cur.ops.append(op)
            cur.symbols[m.group(1)] = m.group(2)
    return comps, entry


_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")


def _operand_names(line: str) -> List[str]:
    m = _OPERANDS_RE.search(line)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dt, out_dims = _shape_dims(op.type_str)
    opnds = _operand_names(op.line)
    if not opnds:
        return 0.0
    lhs_type = comp.symbols.get(opnds[0], "")
    _, lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * contract


def _fusion_traffic(comp: Computation) -> float:
    """HBM traffic of one fusion call: actually-read operand bytes + the
    written bytes.

    * a parameter consumed ONLY by dynamic-slice ops contributes the slice
      sizes, not the full buffer (scan stashes are read one layer-slice at
      a time);
    * if the root is a dynamic-update-slice (in-place stash write under
      buffer aliasing) the write is the update size, not the buffer size.
    """
    if not comp.ops:
        return 0.0
    consumers: Dict[str, List[Op]] = {}
    for op in comp.ops:
        for o in _operand_names(op.line):
            consumers.setdefault(o, []).append(op)

    def _slicey(chain_ops) -> bool:
        """True if every consumer only slices/updates-in-place (possibly
        through converts) — the buffer itself is not streamed."""
        for c in chain_ops:
            if c.opcode in ("dynamic-slice",):
                continue
            if c.opcode == "dynamic-update-slice":
                continue
            if c.opcode in ("convert", "bitcast", "copy"):
                if not _slicey(consumers.get(c.name, [])):
                    return False
                continue
            return False
        return True

    total = 0.0
    for op in comp.ops:
        if op.opcode != "parameter":
            continue
        cons = consumers.get(op.name, [])
        if cons and _slicey(cons):
            # count only the sliced reads; in-place DUS buffers are free
            # (the update write is the root / another param)
            def _slice_bytes(ops_):
                t = 0
                for c in ops_:
                    if c.opcode == "dynamic-slice":
                        t += _shape_bytes(c.type_str)
                    elif c.opcode in ("convert", "bitcast", "copy"):
                        t += _slice_bytes(consumers.get(c.name, []))
                return t
            total += _slice_bytes(cons)
        else:
            total += _shape_bytes(op.type_str)

    # root write: walk back through converts to find an in-place DUS
    root = comp.ops[-1]
    seen = root
    while seen.opcode in ("convert", "bitcast", "copy"):
        ops_ = _operand_names(seen.line)
        prev = next((o for o in comp.ops if o.name == (ops_[0] if ops_
                                                       else "")), None)
        if prev is None:
            break
        seen = prev
    if seen.opcode == "dynamic-update-slice":
        opnds = _operand_names(seen.line)
        if len(opnds) >= 2 and opnds[1] in comp.symbols:
            total += _shape_bytes(comp.symbols[opnds[1]])
        else:
            total += _shape_bytes(seen.type_str)
    else:
        total += _shape_bytes(root.type_str)
    return total


# opcodes whose call-site bytes are handled elsewhere or are free
_NO_BYTES = ("parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "while", "fusion", "conditional", "after-all",
             "partition-id", "replica-id")


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult


def analyze(text: str) -> dict:
    comps, entry = parse_module(text)
    memo: Dict[Tuple[str, bool], Cost] = {}

    def resolve(name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in memo:
            return memo[key]
        memo[key] = Cost()          # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        c = Cost()
        for op in comp.ops:
            if op.opcode == "dot":
                c.flops += _dot_flops(op, comp)
            kind = next((k for k in COLLECTIVES
                         if op.opcode in (k, k + "-start")), None)
            if kind is not None:
                nb = _shape_bytes(op.type_str)
                c.coll_bytes[kind] = c.coll_bytes.get(kind, 0.0) + nb
                c.coll_counts[kind] = c.coll_counts.get(kind, 0.0) + 1
                if "f32[" in op.type_str:
                    # tracked separately: XLA:CPU float-normalization
                    # promotes bf16 compute to f32 BEFORE partitioning, so
                    # collectives that are bf16 on TPU appear as f32 here
                    # (roofline applies the dtype correction).
                    c.coll_bytes[kind + "_f32"] = \
                        c.coll_bytes.get(kind + "_f32", 0.0) + nb
            if top_level and op.opcode not in _NO_BYTES:
                if op.opcode == "dynamic-update-slice":
                    # in-place write: update read+write, buffer untouched
                    opnds = _operand_names(op.line)
                    if len(opnds) >= 2 and opnds[1] in comp.symbols:
                        c.bytes += 2 * _shape_bytes(comp.symbols[opnds[1]])
                else:
                    c.bytes += _shape_bytes(op.type_str)
                    for o in _operand_names(op.line):
                        if o in comp.symbols:
                            c.bytes += _shape_bytes(comp.symbols[o])
            if top_level and op.opcode == "fusion":
                m = _CALLS_RE.search(op.line)
                if m and m.group(1) in comps:
                    c.bytes += _fusion_traffic(comps[m.group(1)])
            # --- call edges ---
            if op.opcode == "while":
                trip = 1
                m = _TRIP_RE.search(op.line)
                if m:
                    trip = int(m.group(1))
                b = _BODY_RE.search(op.line)
                if b:
                    c.add(resolve(b.group(1), top_level), trip)
                cd = _COND_RE.search(op.line)
                if cd:
                    c.add(resolve(cd.group(1), top_level), trip + 1)
            elif op.opcode == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    c.add(resolve(m.group(1), False), 1.0)
            elif op.opcode in ("call", "custom-call", "sort", "reduce",
                               "reduce-window", "scatter", "select-and-scatter",
                               "map", "all-reduce", "reduce-scatter"):
                m = _TOAPPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if m:
                    c.add(resolve(m.group(1), False), 1.0)
            elif op.opcode == "conditional":
                branches = []
                m = _BRANCHES_RE.search(op.line)
                if m:
                    branches = re.findall(r"%([\w.\-]+)", m.group(1))
                else:
                    branches = _TF_RE.findall(op.line)
                if branches:
                    costs = [resolve(b, top_level) for b in branches]
                    worst = max(costs, key=lambda x: x.flops)
                    c.add(worst, 1.0)
        memo[key] = c
        return c

    total = resolve(entry, True) if entry else Cost()
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "collectives": {k: {"bytes": total.coll_bytes.get(k, 0.0),
                            "count": total.coll_counts.get(k, 0.0),
                            "f32_bytes": total.coll_bytes.get(k + "_f32",
                                                              0.0)}
                        for k in COLLECTIVES},
        "collective_total_bytes": sum(
            v for k, v in total.coll_bytes.items()
            if not k.endswith("_f32")),
    }


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=1))
