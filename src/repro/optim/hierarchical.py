"""Hierarchical multi-pod gradient reduction with cross-pod compression.

On a (pod, data, model) fleet the data-parallel gradient reduction spans
pod x data, but the cross-pod links are the scarce resource (DCN or
long-haul ICI vs in-pod ICI).  This module implements the standard
hierarchy with the paper-flavored twist (DESIGN.md §7.3):

    1. exact psum over the in-pod 'data' axis (fast links, full precision)
    2. ternary quantization with error feedback (optim/compress.py — the
       Achlioptas {-s,0,+s} machinery applied to gradients)
    3. psum of the compressed representation over the 'pod' axis
       (wire cost modeled at 2 bits/elem + scale: ~16x less than f32)
    4. decode and average

Error feedback makes the compression unbiased over steps (the residual is
re-injected next step), so SGD converges on the exact gradient average in
the telescoping sense — property-tested in tests/test_hierarchical.py on
a real (pod=2, data=k) host mesh.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.optim.compress import ternarize


def hierarchical_grad_reduce(g: jax.Array, err: jax.Array,
                             pod_axis: str = "pod",
                             data_axis: str = "data",
                             threshold_frac: float = 0.7,
                             compress: bool = True
                             ) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: per-shard grad -> fleet-average grad.

    g:   this shard's local gradient (identical shape everywhere)
    err: this shard's error-feedback buffer (same shape)
    Returns (averaged gradient, new error buffer)."""
    n_data = jax.lax.psum(1, data_axis)
    n_pod = jax.lax.psum(1, pod_axis)
    # stage 1: exact in-pod average
    g_pod = jax.lax.psum(g, data_axis) / n_data
    if not compress:
        return jax.lax.psum(g_pod, pod_axis) / n_pod, err
    # stage 2: ternary + error feedback on the cross-pod stream
    corrected = g_pod.astype(jnp.float32) + err
    codes, scale = ternarize(corrected, threshold_frac)
    decoded = codes * scale
    new_err = corrected - decoded
    # stage 3: compressed cross-pod sum.  On the wire this is the psum of
    # 2-bit codes plus one scalar per shard; numerically psum(codes*scale)
    # == sum of per-pod decodings (what each pod would reconstruct).
    g_fleet = jax.lax.psum(decoded, pod_axis) / n_pod
    return g_fleet.astype(g.dtype), new_err


def tree_hierarchical_reduce(grads, errs, **kw):
    """Pytree version for use inside a shard_map'd train step."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        rg, re = hierarchical_grad_reduce(g, e, **kw)
        out_g.append(rg)
        out_e.append(re)
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))
