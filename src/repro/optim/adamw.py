"""AdamW + schedules + global-norm clipping, with ZeRO-1 sharded state.

No optax in this environment — this is a purpose-built, pjit-friendly
implementation.  Optimizer state:
    {"step", "m", "v", "master"(bf16 runs only)}
m/v/master mirror the param tree; `zero1_specs` additionally shards them
over the 'data' axis on the first replicated, divisible dim (ZeRO-1: the
optimizer state, the largest training-memory consumer after activations,
never lives replicated across data-parallel replicas).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"    # "cosine" | "linear" | "const"


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(cfg.warmup, 1))
    if cfg.schedule == "const":
        return cfg.lr * warm
    frac = jnp.clip((s - cfg.warmup) / max(cfg.total_steps - cfg.warmup, 1),
                    0.0, 1.0)
    if cfg.schedule == "linear":
        return cfg.lr * warm * (1.0 - frac)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def init_opt(params: dict, use_master: bool) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    st = {"step": jnp.zeros((), jnp.int32),
          "m": zeros,
          "v": jax.tree.map(jnp.copy, zeros)}
    if use_master:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params: dict, grads: dict, opt: dict,
                  cfg: AdamWConfig) -> tuple:
    """One AdamW step.  Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    masters = opt.get("master", params)

    def upd(p, g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        w32 = w.astype(jnp.float32)
        w_new = w32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                            + cfg.weight_decay * w32)
        return w_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    flat_w = jax.tree.leaves(masters)
    new_w, new_m, new_v, new_p = [], [], [], []
    for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w):
        w2, m2, v2 = upd(p, g, m, v, w)
        new_w.append(w2)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(w2.astype(p.dtype))
    new_opt = {"step": step,
               "m": jax.tree.unflatten(treedef, new_m),
               "v": jax.tree.unflatten(treedef, new_v)}
    if "master" in opt:
        new_opt["master"] = jax.tree.unflatten(treedef, new_w)
    new_params = jax.tree.unflatten(treedef, new_p)
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}


# --- ZeRO-1 spec derivation --------------------------------------------------

def zero1_specs(pspecs, params, data_axis: str = "data"):
    """Optimizer-state specs: param spec + 'data' on the first replicated,
    divisible dim (the classic ZeRO-1 layout under GSPMD)."""

    def rule(spec: P, leaf):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (e, dim) in enumerate(zip(entries, leaf.shape)):
            if e is None and dim % 16 == 0 and dim >= 16:
                entries[i] = data_axis
                return P(*entries)
        return spec

    return jax.tree.map(rule, pspecs, params,
                        is_leaf=lambda x: isinstance(x, P))


def opt_specs(pspecs, params, zero1: bool = True):
    base = zero1_specs(pspecs, params) if zero1 else pspecs
    st = {"step": P(), "m": base, "v": base}
    return st


def opt_specs_with_master(pspecs, params, zero1: bool = True):
    st = opt_specs(pspecs, params, zero1)
    st["master"] = st["m"]
    return st
