"""Ternary gradient compression with error feedback (beyond-paper, §7.3).

Reuses the paper's Achlioptas-ternary machinery on the *gradients*: before
the cross-pod all-reduce, each shard quantizes its gradient block to
{-s, 0, +s} with s = mean(|g|) over the non-zero set (TernGrad-flavored),
keeps the quantization error in a feedback buffer added to the next step's
gradient (error feedback makes the compression unbiased over time).

Wire cost: 2 bits/element packed (we model 1/8 of fp32 = 16x reduction on
the 'pod' axis all-reduce — the slowest links in a multi-pod fleet).
The compressed collective for the SPMD path is expressed as
quantize -> psum -> dequantize; tests verify the error-feedback telescoping
property and convergence on a quadratic problem.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ternarize(g: jax.Array, threshold_frac: float = 0.7) -> Tuple[jax.Array, jax.Array]:
    """g -> (ternary codes in {-1,0,+1} as int8-semantics float, scale).

    threshold: |g| > threshold_frac * mean|g| participates; scale preserves
    E[decoded] = E[g] over the kept set."""
    g32 = g.astype(jnp.float32)
    mean_abs = jnp.mean(jnp.abs(g32))
    thr = threshold_frac * mean_abs
    codes = jnp.sign(g32) * (jnp.abs(g32) > thr)
    kept = jnp.maximum(jnp.sum(jnp.abs(codes)), 1.0)
    scale = jnp.sum(jnp.abs(g32) * jnp.abs(codes)) / kept
    return codes, scale


def decode(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes * scale


def compress_with_feedback(g: jax.Array, err: jax.Array,
                           threshold_frac: float = 0.7):
    """(gradient, error buffer) -> (decoded gradient, new error buffer)."""
    corrected = g.astype(jnp.float32) + err
    codes, scale = ternarize(corrected, threshold_frac)
    dec = decode(codes, scale)
    new_err = corrected - dec
    return dec, new_err


def compressed_psum(g: jax.Array, axis: str, err: jax.Array,
                    threshold_frac: float = 0.7):
    """Ternary-compressed all-reduce over `axis` (shard_map context).

    Each participant sends codes (2-bit wire format) + one scalar scale;
    the psum of decoded values equals the psum of per-shard ternary
    approximations.  Returns (reduced, new_err)."""
    dec, new_err = compress_with_feedback(g, err, threshold_frac)
    return jax.lax.psum(dec, axis), new_err


def tree_compress_with_feedback(grads, errs, threshold_frac: float = 0.7):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    dec, errs_new = [], []
    for g, e in zip(flat_g, flat_e):
        d, ne = compress_with_feedback(g, e, threshold_frac)
        dec.append(d.astype(g.dtype))
        errs_new.append(ne)
    return (jax.tree.unflatten(treedef, dec),
            jax.tree.unflatten(treedef, errs_new))


def init_feedback(grads_template):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_template)


def wire_bytes(g: jax.Array) -> int:
    """Modeled wire bytes for the compressed representation."""
    return (g.size * 2 + 7) // 8 + 4   # 2 bits/elem + fp32 scale
