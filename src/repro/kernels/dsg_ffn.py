"""DSG block-sparse SwiGLU FFN — the flagship Pallas TPU kernel.

Realizes the paper's compute saving at MXU granularity: the FFN hidden dim
F is split into 128-wide neuron groups; for each (token-tile, group-block)
cell the kernel consults a tile-level mask and SKIPS the gate/up matmuls,
the SwiGLU, and the down-projection accumulation for masked-out blocks —
the "reorder executions at tile granularity and group non-redundant work"
strategy the paper sketches for GEMM backends (§3.4), here done natively.

Exactness: the tile mask is the OR of the per-token DRS masks over the
token tile; per-token masks are re-applied elementwise inside the kernel,
so the output equals the reference masked FFN bit-for-bit (a block runs if
any token in the tile selected it, and unselected tokens still contribute
zeros).

Grid: (M/bm, F/bf), F innermost so the output tile (bm, d) accumulates in
VMEM across the F pass (sequential revisiting on TPU).  BlockSpecs keep
the working set at bm*d + 2*d*bf + bf*d + bm*bf floats in VMEM — with
bm=bf=128, d<=8192, bf16: about 6.5 MB, comfortably under the 16 MB/core
of v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, tmask_ref, tokmask_ref, o_ref,
            *, block: int):
    f_idx = pl.program_id(1)

    @pl.when(f_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(tmask_ref[0, 0] > 0)
    def _compute():
        x = x_ref[...]
        g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
        u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u                          # (bm, bf)
        # exact per-token mask within the visited block
        bm, bf = h.shape
        tok = tokmask_ref[...]                          # (bm, bf//block)
        h = (h.reshape(bm, bf // block, block)
             * tok[..., None]).reshape(bm, bf)
        o_ref[...] += jnp.dot(h.astype(x.dtype), wd_ref[...],
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


def dsg_ffn(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
            token_mask: jax.Array, *, block: int = 128, bm: int = 128,
            bf: int = 128, interpret: bool = False) -> jax.Array:
    """x (M, d), wg/wu (d, F), wd (F, d), token_mask (M, F//block) {0,1}.

    Returns (M, d).  bf must be a multiple of `block`.
    """
    m, d = x.shape
    f = wg.shape[1]
    bm = min(bm, m)
    bf = min(bf, f)
    assert m % bm == 0 and f % bf == 0 and bf % block == 0
    gpb = bf // block                                  # groups per f-block
    mt, ft = m // bm, f // bf

    # tile mask: OR of token masks over each (token-tile, f-block) cell
    tile_mask = token_mask.reshape(mt, bm, ft, gpb).max(axis=(1, 3))
    tile_mask = tile_mask.astype(jnp.float32)

    grid = (mt, ft)
    return pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((bm, gpb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, wg, wu, wd, tile_mask, token_mask.astype(jnp.float32))
