"""DSG block-sparse SwiGLU FFN — the flagship Pallas TPU kernel.

Realizes the paper's compute saving at MXU granularity: the FFN hidden dim
F is split into 128-wide neuron groups; for each (token-tile, group-block)
cell the kernel consults a tile-level mask and SKIPS the gate/up matmuls,
the SwiGLU, and the down-projection accumulation for masked-out blocks —
the "reorder executions at tile granularity and group non-redundant work"
strategy the paper sketches for GEMM backends (§3.4), here done natively.

Exactness: the tile mask is the OR of the per-token DRS masks over the
token tile; per-token masks are re-applied elementwise inside the kernel,
so the output equals the reference masked FFN bit-for-bit (a block runs if
any token in the tile selected it, and unselected tokens still contribute
zeros).

Grid: (M/bm, F/bf), F innermost so the output tile (bm, d) accumulates in
VMEM across the F pass (sequential revisiting on TPU).  BlockSpecs keep
the working set at bm*d + 2*d*bf + bf*d + bm*bf floats in VMEM — with
bm=bf=128, d<=8192, bf16: about 6.5 MB, comfortably under the 16 MB/core
of v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, tmask_ref, tokmask_ref, o_ref,
            *, block: int):
    f_idx = pl.program_id(1)

    @pl.when(f_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(tmask_ref[0, 0] > 0)
    def _compute():
        x = x_ref[...]
        g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
        u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u                          # (bm, bf)
        # exact per-token mask within the visited block
        bm, bf = h.shape
        tok = tokmask_ref[...]                          # (bm, bf//block)
        h = (h.reshape(bm, bf // block, block)
             * tok[..., None]).reshape(bm, bf)
        o_ref[...] += jnp.dot(h.astype(x.dtype), wd_ref[...],
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


def dsg_ffn(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
            token_mask: jax.Array, *, block: int = 128, bm: int = 128,
            bf: int = 128, interpret: bool = False) -> jax.Array:
    """x (M, d), wg/wu (d, F), wd (F, d), token_mask (M, F//block) {0,1}.

    Returns (M, d).  bf must be a multiple of `block`.
    """
    m, d = x.shape
    f = wg.shape[1]
    bm = min(bm, m)
    bf = min(bf, f)
    assert m % bm == 0 and f % bf == 0 and bf % block == 0
    gpb = bf // block                                  # groups per f-block
    mt, ft = m // bm, f // bf

    # tile mask: OR of token masks over each (token-tile, f-block) cell
    tile_mask = token_mask.reshape(mt, bm, ft, gpb).max(axis=(1, 3))
    tile_mask = tile_mask.astype(jnp.float32)

    grid = (mt, ft)
    return pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((bm, gpb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, wg, wu, wd, tile_mask, token_mask.astype(jnp.float32))


# ---------------------------------------------------------------------------
# CSR-driven decode variant
# ---------------------------------------------------------------------------

def _csr_kernel(idx_ref, cnt_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One (lane, csr-slot) cell: the index maps below already steered the
    gate/up/down weight *blocks* of group idx[b, j] into VMEM, so the body
    is a dense (1, d) x (d, blk) SwiGLU + down-projection, skipped for
    padded slots past the lane's count."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j < cnt_ref[b])
    def _compute():
        x = x_ref[...]                                    # (1, d)
        g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
        u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u                            # (1, blk)
        o_ref[...] += jnp.dot(h.astype(x.dtype), wd_ref[...],
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


def dsg_ffn_csr(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                idx: jax.Array, counts: jax.Array, *, block: int = 128,
                interpret: bool = False) -> jax.Array:
    """Group-CSR SwiGLU decode: walk each lane's active-group index list
    instead of scanning a dense tile mask.

    x (B, d) one token per lane, wg/wu (d, F), wd (F, d),
    idx (B, K) active group indices (core/sparse_mask.py layout: ascending
    per lane, zero-padded past counts), counts (B,) -> (B, d).

    Grid (B, K), K innermost so the (1, d) output row accumulates in VMEM
    across the walk.  The index list is scalar-prefetched (the
    paged-attention page-table idiom): the weight-block index maps read
    `idx[b, j]` directly, so ONLY the kept groups' gate/up/down blocks
    ever leave HBM — weight traffic scales with counts, not F.  Padded
    slots clamp to the last active block (the consecutive-identical-index
    elision skips the re-fetch) and `pl.when` skips their compute."""
    b, d = x.shape
    f = wg.shape[1]
    k = idx.shape[1]
    assert f % block == 0 and k <= f // block

    def _wcol(bb, jj, idx_p, cnt_p):
        # clamp padded slots onto the lane's last active block: identical
        # consecutive indices -> the pipeline elides the HBM fetch
        return idx_p[bb, jnp.minimum(jj, jnp.maximum(cnt_p[bb], 1) - 1)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # idx, counts
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, d), lambda bb, jj, idx_p, cnt_p: (bb, 0)),
            pl.BlockSpec((d, block),
                         lambda bb, jj, idx_p, cnt_p: (0, _wcol(bb, jj, idx_p, cnt_p))),
            pl.BlockSpec((d, block),
                         lambda bb, jj, idx_p, cnt_p: (0, _wcol(bb, jj, idx_p, cnt_p))),
            pl.BlockSpec((block, d),
                         lambda bb, jj, idx_p, cnt_p: (_wcol(bb, jj, idx_p, cnt_p), 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bb, jj, idx_p, cnt_p: (bb, 0)),
    )
    return pl.pallas_call(
        _csr_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), counts.astype(jnp.int32), x, wg, wu, wd)
