"""Flash attention (Pallas TPU): online-softmax attention whose score
tiles never leave VMEM.

Motivation (EXPERIMENTS.md §Roofline): the prefill_32k cells are
memory-bound on the (B,H,S,T)-scale score/probability traffic of the
XLA-level attention chain (e.g. llava-next-34b prefill: 59 s memory term
vs 2.6 s compute).  This kernel holds the (block_q, block_k) score tile
and the (block_q,) running max/sum in VMEM scratch across the key pass —
HBM traffic drops to Q/K/V/O streaming:

    traffic_flash ~ B*H*(S*D*3 + S*D) * bytes        (vs + B*H*S*T*c f32)

Grid: (B*H, S/block_q, T/block_k), key-block innermost so the scratch
accumulators carry across the revisit (sequential TPU grid).  Causal
masking uses absolute indices; fully-masked key blocks short-circuit via
pl.when (real skipped MXU work for the upper triangle).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            nk: int, offset: int):
    """offset = T - S: query row i holds absolute position i + offset
    (decode/suffix convention — matches jnp.tril(..., T - S))."""
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * block_q + offset
    k_start = kb * block_k
    # causal: the whole key block is in the future -> nothing to do
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale       # (bq, bk)
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
            ki = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
            s = jnp.where(ki <= qi, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(ki <= qi, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(kb == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (BH, S, D), k/v (BH, T, D) -> (BH, S, D).

    Heads are pre-flattened into the leading dim (callers fold B*H; GQA
    callers repeat or group upstream)."""
    bh, s_len, d = q.shape
    t_len = k.shape[1]
    block_q = min(block_q, s_len)
    block_k = min(block_k, t_len)
    assert s_len % block_q == 0 and t_len % block_k == 0
    nq, nk = s_len // block_q, t_len // block_k
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, nk=nk,
                          offset=t_len - s_len if causal else 0),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_len, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),     # running max
            pltpu.VMEM((block_q,), jnp.float32),     # running sum
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
