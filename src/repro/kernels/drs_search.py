"""DRS Pallas kernels: projection and virtual-score computation.

drs_project: f(X) = X @ R^T — the dimension reduction itself.  On the MXU
the ternary structure of R buys nothing over a dense matmul (DESIGN.md §2),
so the kernel is a straight tiled matmul with k (the projected dim, a
multiple of the 128 lane width by construction in projection.jll_dim).

drs_scores: virtual pre-activations v = f(X) @ f(W), ReLU, and per-group
reduction fused in one pass — the low-dimensional search the paper
substitutes for the full VMM.  The (bm, bf) virtual-activation tile never
leaves VMEM; only the (bm, bf/block) group scores are written to HBM —
the kernel's HBM traffic is 1/block of the naive two-op formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _project_kernel(x_ref, rt_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], rt_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def drs_project(x: jax.Array, r: jax.Array, *, bm: int = 128,
                interpret: bool = False) -> jax.Array:
    """x (M, d), r (k, d) -> f(X) (M, k)."""
    m, d = x.shape
    k = r.shape[0]
    bm = min(bm, m)
    assert m % bm == 0
    return pl.pallas_call(
        _project_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((d, k), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=interpret,
    )(x, r.T)


def _scores_kernel(fx_ref, fw_ref, o_ref, *, block: int):
    v = jnp.dot(fx_ref[...], fw_ref[...],
                preferred_element_type=jnp.float32)      # (bm, bf)
    bm, bf = v.shape
    relu = jnp.maximum(v, 0.0)
    o_ref[...] = relu.reshape(bm, bf // block, block).sum(-1).astype(
        o_ref.dtype)


def drs_scores(fx: jax.Array, fw: jax.Array, *, block: int = 128,
               bm: int = 128, bf: int = 512,
               interpret: bool = False) -> jax.Array:
    """fx (M, k), fw (k, F) -> group scores (M, F/block)."""
    m, k = fx.shape
    f = fw.shape[1]
    bm = min(bm, m)
    bf = min(bf, f)
    assert m % bm == 0 and f % bf == 0 and bf % block == 0
    return pl.pallas_call(
        functools.partial(_scores_kernel, block=block),
        grid=(m // bm, f // bf),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bf), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bf // block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, f // block), jnp.float32),
        interpret=interpret,
    )(fx, fw)
