"""Paged-attention decode (Pallas TPU): fused page-table scatter +
depth-bounded page walk + flash-decode online softmax.

Motivation (ROADMAP "Pallas gather kernel for decode"): the XLA paged
decode path gathers the full `max_pages * page_size` logical window
through the page table every step, so a lane 40 tokens deep still
streams the worst-case window from HBM.  The DSG discipline — the
executor must read *only* the activated subset — applies to the serving
memory plane too: per decode step, a lane's live state is exactly the
pages at or below `pos // page_size`.  This kernel walks only those.

Layout (serving/kv_cache.py PagedBackend, one layer's slice):

    k_pages / v_pages : (P, page_size, Kv, D)   physical page pool
    page_table        : (B, max_pages) int32    logical -> physical
    pos               : (B,) int32              per-lane write position
                                                (== the new token's
                                                absolute position)

Grid: (B, Kv, n_pages), page index innermost so the per-(lane, kv-head)
flash accumulators carry across the page walk in VMEM scratch.  The page
table and per-lane depths ride as scalar prefetch, so BlockSpec index
maps resolve logical->physical page ids before each block fetch:

  * depth bounding — the K/V page index map clamps the logical page at
    the lane's depth, `pt[b, min(j, pos[b] // ps)]`; every grid cell
    past the depth maps to the same physical block as its predecessor,
    and the pipeline's consecutive-identical-index elision skips the
    copy, so pages past the lane's depth are never fetched from HBM.
    `pl.when(j <= pos // ps)` skips their compute as well.
  * fused scatter — the new token's K/V row is inserted into the
    gathered tile in VMEM (row `pos % ps` of logical page `pos // ps`),
    and that updated tile is the kernel's K/V-pool output block (the
    pools are input/output aliased; the output index map pins the write
    page for the whole walk, so exactly one page per (lane, kv head) is
    written back).  Attention therefore sees the new token without a
    separate XLA scatter pass.
  * masking convention — row r of logical page j holds absolute
    position t = j * ps + r; valid iff t <= pos (the new token attends
    itself, matching the dense path's `kp <= qp`) and, for sliding
    windows, t > pos - window.  The partial final page's tail (t > pos)
    reads whatever the pool holds — junk is masked by position, exactly
    as unwritten dense slots are.

Lanes that share a page-table row (the scheduler mirrors retired lanes
onto a donor lane) scatter identical rows to the same physical page, so
the duplicate write-back is order-independent — the same argument that
makes the XLA scatter's duplicate-index semantics safe.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(pt_ref, pos_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
            o_ref, ko_ref, vo_ref, m_scr, l_scr, acc_scr, *,
            scale: float, ps: int, window: int, n_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]
    lp = pos // ps                   # lane's deepest live logical page
    off = pos % ps                   # new token's row in that page
    # write page clamped to the walk: with a correctly sized walk wp == lp;
    # an undersized walk (caller bug) degrades to an identity write-back
    # of page walk-1 instead of flushing uninitialized VMEM over live K/V
    wp = jnp.minimum(lp, n_pages - 1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j <= lp)
    def _compute():
        # insert the new token's row into the gathered tile (VMEM): cast
        # to the pool dtype FIRST so the attended values match the XLA
        # scatter (`pool.at[pp, off].set(k_new.astype(pool.dtype))`)
        row = jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
        ins = (j == lp) & (row == off)
        k_t = jnp.where(ins, kn_ref[0, 0][None, :].astype(ko_ref.dtype),
                        kp_ref[0, :, 0, :])
        v_t = jnp.where(ins, vn_ref[0, 0][None, :].astype(vo_ref.dtype),
                        vp_ref[0, :, 0, :])

        @pl.when(j == wp)
        def _scatter():
            # one page write-back per (lane, kv head): the output index
            # map pins the physical write page across the whole walk
            ko_ref[0, :, 0, :] = k_t
            vo_ref[0, :, 0, :] = v_t

        qg = q_ref[0, 0].astype(jnp.float32)            # (g, D)
        s = jax.lax.dot_general(
            qg, k_t.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, ps)
        g = s.shape[0]
        t_abs = j * ps + jax.lax.broadcasted_iota(jnp.int32, (g, ps), 1)
        valid = t_abs <= pos
        if window > 0:
            valid &= t_abs > pos - window
        s = jnp.where(valid, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v_t.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                 k_pages: jax.Array, v_pages: jax.Array,
                 page_table: jax.Array, pos: jax.Array, *,
                 window: int = 0, num_pages: int = 0,
                 interpret: bool = False):
    """One fused decode step over the paged KV layout.

    q (B, H, D) — the step's queries (RoPE already applied);
    k_new/v_new (B, Kv, D) — the new token's K/V; k_pages/v_pages
    (P, ps, Kv, D) — one layer's physical pools; page_table
    (B, max_pages) int32; pos (B,) int32 per-lane write positions.
    Returns (o (B, H, D), k_pages', v_pages') with the new rows
    scattered into the pools.

    num_pages statically bounds the page walk (the serving scheduler
    passes its bucketed live-page bound so the grid shrinks with actual
    batch depth); it must cover every lane: num_pages > max(pos) // ps.
    An undersized bound cannot corrupt the pools (the write-back page is
    clamped into the walk, degrading to an identity rewrite) but the
    truncated window yields wrong attention output and the new token is
    not persisted — the bound is the caller's contract.  Every logical
    page 0..pos//ps of each lane must be mapped in the page table (the
    backend's `ensure` guarantees this for live lanes; retired lanes
    must be mirrored onto a live donor row).

    Softmax statistics and the score tile are f32 regardless of
    `attn_bf16_scores`: that flag is an HBM-traffic lever for the XLA
    attention chain, and the kernel's score tile never leaves VMEM — so
    parity with a bf16-scores XLA path is tolerance-level (standard
    flash-kernel numerics), while the f32 path matches bitwise at the
    token-stream level.
    """
    b, h, d = q.shape
    n_p, ps, kv, _ = k_pages.shape
    assert h % kv == 0, f"H={h} not a multiple of Kv={kv}"
    g = h // kv
    max_pages = page_table.shape[1]
    walk = min(num_pages, max_pages) if num_pages else max_pages
    q4 = q.reshape(b, kv, g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # page_table, pos
        grid=(b, kv, walk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, jj, pt, ps_: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda bb, hh, jj, pt, ps_: (bb, hh, 0)),
            pl.BlockSpec((1, 1, d), lambda bb, hh, jj, pt, ps_: (bb, hh, 0)),
            # depth-clamped physical page: cells past the lane's depth
            # alias their predecessor's block -> the pipeline elides the
            # fetch (pages past `pos` never leave HBM)
            pl.BlockSpec((1, ps, 1, d),
                         lambda bb, hh, jj, pt, ps_: (
                             pt[bb, jnp.minimum(jj, ps_[bb] // ps)],
                             0, hh, 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda bb, hh, jj, pt, ps_: (
                             pt[bb, jnp.minimum(jj, ps_[bb] // ps)],
                             0, hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, jj, pt, ps_: (bb, hh, 0, 0)),
            # write page pinned for the whole walk -> one write-back per
            # (lane, kv head), flushed when the block index changes (the
            # walk clamp mirrors the kernel's wp, see _kernel)
            pl.BlockSpec((1, ps, 1, d),
                         lambda bb, hh, jj, pt, ps_: (
                             pt[bb, jnp.minimum(ps_[bb] // ps, walk - 1)],
                             0, hh, 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda bb, hh, jj, pt, ps_: (
                             pt[bb, jnp.minimum(ps_[bb] // ps, walk - 1)],
                             0, hh, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),      # running max
            pltpu.VMEM((g,), jnp.float32),      # running sum
            pltpu.VMEM((g, d), jnp.float32),    # output accumulator
        ],
    )
    o, kp, vp = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(d), ps=ps,
                          window=window, n_pages=walk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        # flat operand indices include the 2 scalar-prefetch args:
        # 5 = k_pages, 6 = v_pages alias pool outputs 1, 2 (in-place)
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32),
      q4, k_new, v_new, k_pages, v_pages)
    return o.reshape(b, h, d), kp, vp
