"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the
kernel body runs as a traced grid loop, validating logic and BlockSpec
indexing exactly as the Mosaic compiler would see them.  On TPU the same
call sites compile natively.

`REPRO_INTERPRET=1` (or `=0`) overrides the backend sniffing, so
tests/CI can force interpret mode explicitly (e.g. when a TPU is
attached but the suite wants the interpreter's exact semantics).  The
flag is read at trace time: flipping it after a wrapper has already
compiled for a given shape will not retrace that shape.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels import (drs_search, dsg_ffn, flash_attention as fa,
                           paged_attention)


def _interpret() -> bool:
    """True when Pallas kernels should run in interpret mode.

    REPRO_INTERPRET=1/0 wins when set; otherwise interpret iff the
    default backend is CPU (no Mosaic compiler there)."""
    env = os.environ.get("REPRO_INTERPRET", "")
    if env != "":
        return env != "0"
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("bm",))
def drs_project(x, r, bm: int = 128):
    return drs_search.drs_project(x, r, bm=bm, interpret=_interpret())


@partial(jax.jit, static_argnames=("block", "bm", "bf"))
def drs_scores(fx, fw, block: int = 128, bm: int = 128, bf: int = 512):
    return drs_search.drs_scores(fx, fw, block=block, bm=bm, bf=bf,
                                 interpret=_interpret())


@partial(jax.jit, static_argnames=("block", "bm", "bf"))
def dsg_ffn_fwd(x, wg, wu, wd, token_mask, block: int = 128,
                bm: int = 128, bf: int = 128):
    return dsg_ffn.dsg_ffn(x, wg, wu, wd, token_mask, block=block,
                           bm=bm, bf=bf, interpret=_interpret())


@partial(jax.jit, static_argnames=("block",))
def dsg_ffn_csr(x, wg, wu, wd, idx, counts, block: int = 128):
    """Group-CSR SwiGLU decode step (kernels/dsg_ffn.dsg_ffn_csr): walk
    each lane's active-group index list — x (B, d), idx (B, K),
    counts (B,) -> (B, d).  K is the static active-group bound
    (core/sparse_mask.active_group_bound)."""
    return dsg_ffn.dsg_ffn_csr(x, wg, wu, wd, idx, counts, block=block,
                               interpret=_interpret())


def dsg_ffn_full(x, wg, wu, wd, r, fw, gamma: float, block: int = 128):
    """End-to-end DSG FFN through the kernels: project -> scores ->
    shared-threshold mask -> block-skip FFN.  Mirrors the pure-JAX
    swiglu_dsg_mask path; used by benchmarks and the kernel parity tests."""
    from repro.core import drs as drs_mod
    fx = drs_project(x, r)
    scores = drs_scores(fx, fw, block=block)
    cfg = drs_mod.DRSConfig(gamma=gamma, block=block, threshold_mode="topk")
    mask, _ = drs_mod.select_mask(scores, fw.shape[1], cfg)
    return dsg_ffn_fwd(x, wg, wu, wd, mask, block=block)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=_interpret())


@partial(jax.jit, static_argnames=("window", "num_pages"))
def paged_decode_attention(q, k_new, v_new, k_pages, v_pages, page_table,
                           pos, window: int = 0, num_pages: int = 0):
    """Fused paged decode step (kernels/paged_attention.py): scatter the
    new token's K/V through the page table, walk only the pages at or
    below each lane's `pos`, flash-decode online softmax.

    q (B, H, D), k_new/v_new (B, Kv, D), k_pages/v_pages (P, ps, Kv, D),
    page_table (B, max_pages), pos (B,) -> (o (B, H, D), k_pages',
    v_pages').  `num_pages` statically bounds the walk (0 = all); it
    must exceed max(pos) // page_size."""
    return paged_attention.paged_decode(
        q, k_new, v_new, k_pages, v_pages, page_table, pos,
        window=window, num_pages=num_pages, interpret=_interpret())
