"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the
kernel body runs in Python per grid cell, validating logic and BlockSpec
indexing exactly as the Mosaic compiler would see them.  On TPU the same
call sites compile natively.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import drs_search, dsg_ffn, flash_attention as fa, ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("bm",))
def drs_project(x, r, bm: int = 128):
    return drs_search.drs_project(x, r, bm=bm, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("block", "bm", "bf"))
def drs_scores(fx, fw, block: int = 128, bm: int = 128, bf: int = 512):
    return drs_search.drs_scores(fx, fw, block=block, bm=bm, bf=bf,
                                 interpret=_on_cpu())


@partial(jax.jit, static_argnames=("block", "bm", "bf"))
def dsg_ffn_fwd(x, wg, wu, wd, token_mask, block: int = 128,
                bm: int = 128, bf: int = 128):
    return dsg_ffn.dsg_ffn(x, wg, wu, wd, token_mask, block=block,
                           bm=bm, bf=bf, interpret=_on_cpu())


def dsg_ffn_full(x, wg, wu, wd, r, fw, gamma: float, block: int = 128):
    """End-to-end DSG FFN through the kernels: project -> scores ->
    shared-threshold mask -> block-skip FFN.  Mirrors the pure-JAX
    swiglu_dsg_mask path; used by benchmarks and the kernel parity tests."""
    from repro.core import drs as drs_mod
    fx = drs_project(x, r)
    scores = drs_scores(fx, fw, block=block)
    cfg = drs_mod.DRSConfig(gamma=gamma, block=block, threshold_mode="topk")
    mask, _ = drs_mod.select_mask(scores, fw.shape[1], cfg)
    return dsg_ffn_fwd(x, wg, wu, wd, mask, block=block)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=_on_cpu())
