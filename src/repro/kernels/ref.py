"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def drs_project_ref(x: jax.Array, r: jax.Array) -> jax.Array:
    """f(X) = X @ R^T.  x (M, d), r (k, d) -> (M, k).

    R is the Achlioptas ternary projection (already scaled by 1/sqrt(k));
    on the MXU this is an ordinary small matmul (DESIGN.md §2)."""
    return x @ r.T


def drs_scores_ref(fx: jax.Array, fw: jax.Array, block: int) -> jax.Array:
    """Virtual activations + per-group post-ReLU mass.

    fx (M, k), fw (k, F) -> scores (M, F/block)."""
    v = fx @ fw
    m, f = v.shape
    return jax.nn.relu(v).reshape(m, f // block, block).sum(-1)


def dsg_ffn_ref(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                token_mask: jax.Array, block: int) -> jax.Array:
    """Masked SwiGLU FFN oracle.

    x (M, d); wg/wu (d, F); wd (F, d); token_mask (M, F/block) in {0,1}.
    y = (silu(x@wg) * (x@wu) * expand(mask)) @ wd."""
    h = jax.nn.silu(x @ wg) * (x @ wu)
    m, f = h.shape
    hm = h.reshape(m, f // block, block) * token_mask[..., None]
    return hm.reshape(m, f) @ wd


def masked_matmul_ref(x: jax.Array, w: jax.Array, token_mask: jax.Array,
                      block: int) -> jax.Array:
    """Column-block-masked matmul oracle: y = (x @ w) * expand(mask).

    x (M, d), w (d, F), token_mask (M, F/block)."""
    y = x @ w
    m, f = y.shape
    ym = y.reshape(m, f // block, block) * token_mask[..., None]
    return ym.reshape(m, f)


def paged_decode_ref(q, k_new, v_new, k_pages, v_pages, page_table, pos,
                     window: int = 0):
    """Oracle for the paged decode kernel: scatter the new token through
    the page table, gather the full logical window, masked softmax.

    q (B, H, D), k_new/v_new (B, Kv, D), k_pages/v_pages (P, ps, Kv, D),
    page_table (B, max_pages) int32, pos (B,) int32 -> (o, k_pages',
    v_pages').  Mirrors the whole-window XLA paged branch of
    models/attention.self_attention bit-for-bit (same scatter casts,
    same `t <= pos` mask, full-precision softmax).
    """
    import math
    b, h, d = q.shape
    ps = k_pages.shape[1]
    kv = k_pages.shape[2]
    lanes = jnp.arange(b)
    pp = page_table[lanes, pos // ps]
    off = pos % ps
    kp = k_pages.at[pp, off].set(k_new.astype(k_pages.dtype))
    vp = v_pages.at[pp, off].set(v_new.astype(v_pages.dtype))
    t = jnp.arange(page_table.shape[1] * ps)
    k = kp[page_table[:, t // ps], t % ps]          # (B, T, Kv, D)
    v = vp[page_table[:, t // ps], t % ps]
    k = jnp.repeat(k, h // kv, axis=2)              # (B, T, H, D)
    v = jnp.repeat(v, h // kv, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    valid = t[None, None, :] <= pos[:, None, None]
    if window > 0:
        valid &= t[None, None, :] > (pos[:, None, None] - window)
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bht,bthd->bhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype), kp, vp


def flash_attention_ref(q, k, v, causal=True):
    """Oracle for the flash kernel: full-softmax attention.
    q (BH, S, D), k/v (BH, T, D)."""
    import math
    s_len, t_len = q.shape[1], k.shape[1]
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        mask = jnp.tril(jnp.ones((s_len, t_len), bool), t_len - s_len)
        sc = jnp.where(mask, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
