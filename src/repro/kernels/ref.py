"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def drs_project_ref(x: jax.Array, r: jax.Array) -> jax.Array:
    """f(X) = X @ R^T.  x (M, d), r (k, d) -> (M, k).

    R is the Achlioptas ternary projection (already scaled by 1/sqrt(k));
    on the MXU this is an ordinary small matmul (DESIGN.md §2)."""
    return x @ r.T


def drs_scores_ref(fx: jax.Array, fw: jax.Array, block: int) -> jax.Array:
    """Virtual activations + per-group post-ReLU mass.

    fx (M, k), fw (k, F) -> scores (M, F/block)."""
    v = fx @ fw
    m, f = v.shape
    return jax.nn.relu(v).reshape(m, f // block, block).sum(-1)


def dsg_ffn_ref(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                token_mask: jax.Array, block: int) -> jax.Array:
    """Masked SwiGLU FFN oracle.

    x (M, d); wg/wu (d, F); wd (F, d); token_mask (M, F/block) in {0,1}.
    y = (silu(x@wg) * (x@wu) * expand(mask)) @ wd."""
    h = jax.nn.silu(x @ wg) * (x @ wu)
    m, f = h.shape
    hm = h.reshape(m, f // block, block) * token_mask[..., None]
    return hm.reshape(m, f) @ wd


def masked_matmul_ref(x: jax.Array, w: jax.Array, token_mask: jax.Array,
                      block: int) -> jax.Array:
    """Column-block-masked matmul oracle: y = (x @ w) * expand(mask).

    x (M, d), w (d, F), token_mask (M, F/block)."""
    y = x @ w
    m, f = y.shape
    ym = y.reshape(m, f // block, block) * token_mask[..., None]
    return ym.reshape(m, f)


def flash_attention_ref(q, k, v, causal=True):
    """Oracle for the flash kernel: full-softmax attention.
    q (BH, S, D), k/v (BH, T, D)."""
    import math
    s_len, t_len = q.shape[1], k.shape[1]
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        mask = jnp.tril(jnp.ones((s_len, t_len), bool), t_len - s_len)
        sc = jnp.where(mask, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
