"""Sparse random projection (Achlioptas 2001) — the DSG dimension reducer.

The paper projects both activations X and weight columns W_j with one shared
ternary matrix R in {-sqrt(s), 0, +sqrt(s)}^{k x d}, s=3 (67% zeros), and
estimates inner products in the k-dimensional space:

    f(Z) = (1/sqrt(k)) R Z,   <f(X), f(W_j)> ~= <X, W_j>   (JLL, paper Eq. 4)

On TPU a ternary matmul costs the same MXU time as a dense one, so the win
is k << d, not multiplier elision; we keep the ternary distribution for its
variance-1 guarantee (E[R_pq]=0, Var[R_pq]=1) and so the same machinery can
ternarize gradients for the collective-compression path (optim/compress.py).

k is derived from the paper's epsilon via the JLL bound k = c * ln(N) / eps^2
(we use c=4, N = number of rows+cols involved), then rounded up to the TPU
lane width (128) so the projected operand tiles cleanly into the MXU.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

LANE = 128  # TPU lane width; projected dim k is rounded up to this.


def jll_dim(d: int, n_points: int, eps: float, c: float = 4.0,
            lane: int = LANE) -> int:
    """JLL-derived projection dim for approximation error eps.

    k = c * ln(N) / eps^2, clamped to [lane, d] and rounded up to `lane`
    (MXU alignment).  eps is the paper's epsilon knob (Fig. 5(d)).
    """
    if eps <= 0:
        raise ValueError(f"eps must be positive, got {eps}")
    k = int(math.ceil(c * math.log(max(n_points, 2)) / (eps * eps)))
    k = max(lane, min(d, k))
    # round up to lane multiple, but never beyond d (projection cannot expand)
    k = min(d, ((k + lane - 1) // lane) * lane)
    return k


def make_projection(key: jax.Array, k: int, d: int, s: int = 3,
                    dtype=jnp.float32) -> jax.Array:
    """Ternary Achlioptas projection matrix R, shape (k, d).

    P(+sqrt(s)) = P(-sqrt(s)) = 1/(2s), P(0) = 1 - 1/s.  With s=3 this is
    the paper's 67%-sparse ternary matrix.  Scaled by 1/sqrt(k) here so
    f(Z) = R @ Z directly (no separate normalizer at use sites).
    """
    ku, ks = jax.random.split(key)
    u = jax.random.uniform(ku, (k, d))
    sign = jnp.where(jax.random.uniform(ks, (k, d)) < 0.5, 1.0, -1.0)
    r = jnp.where(u < 1.0 / s, sign * math.sqrt(s), 0.0)
    return (r / math.sqrt(k)).astype(dtype)


def project(r: jax.Array, z: jax.Array) -> jax.Array:
    """f(Z) = R @ Z for Z of shape (d, ...) — projects the leading dim.

    For activations laid out (..., d) use `project_rows`.
    """
    return jnp.tensordot(r, z, axes=((1,), (0,)))


def project_rows(r: jax.Array, x: jax.Array) -> jax.Array:
    """f(X) over the trailing feature dim: (..., d) -> (..., k)."""
    return jnp.tensordot(x, r, axes=((-1,), (1,)))


@partial(jax.jit, static_argnames=("refresh_every",))
def maybe_refresh_fw(step: jax.Array, r: jax.Array, w: jax.Array,
                     fw: jax.Array, refresh_every: int = 50) -> jax.Array:
    """Paper Sec. 3.1: the projected weights f(W) are refreshed only every
    `refresh_every` (=50) steps to amortize projection cost.  Between
    refreshes the stale f(W) is used for the search; the paper shows this
    does not hurt selection quality (weights drift slowly)."""
    do = (step % refresh_every) == 0
    return jax.lax.cond(do, lambda: project(r, w).astype(fw.dtype), lambda: fw)
