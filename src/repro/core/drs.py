"""Dimension-reduction search (DRS) — the paper's graph-selection mechanism.

Given projected activations f(X) (..., k) and projected weights f(W) (k, N),
compute *virtual* pre-activations  v = f(X) @ f(W)  in the low-dim space,
score output neurons, and emit a binary selection mask keeping the top
(1 - gamma) fraction (gamma = paper's sparsity knob).

TPU adaptation (DESIGN.md §2): selection granularity is a *neuron group* of
`block` consecutive output neurons (default 128 = MXU lane width) instead of
single neurons.  The group score is sum(relu(v)) over the group — an estimate
of the group's post-ReLU/SiLU L1 mass; a sum of JLL-preserved inner products
is itself preserved, so the paper's guarantee carries over to groups.

Threshold modes (paper Appendix B + DESIGN.md §10.5):
  * "topk"   — exact per-row top-k over groups (jax.lax.top_k).
  * "shared" — paper-faithful inter-sample threshold sharing: the top-k
               threshold is computed on the FIRST row of the batch and
               shared by all rows.
  * "ema"    — beyond-paper: threshold is an exponential moving average
               carried across steps (no per-batch search at all, and no
               cross-`data` collective in the sharded setting).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class DRSConfig(NamedTuple):
    gamma: float = 0.5          # target sparsity (fraction of groups dropped)
    block: int = 128            # neuron-group width (TPU adaptation)
    threshold_mode: str = "topk"   # "topk" | "shared" | "ema"
    ema_decay: float = 0.95     # for threshold_mode == "ema"
    score: str = "relu_sum"     # "relu_sum" | "abs_sum" | "signed_sum"


def num_groups(n_out: int, block: int) -> int:
    if n_out % block != 0:
        raise ValueError(f"n_out={n_out} not divisible by block={block}")
    return n_out // block


def keep_groups(n_out: int, cfg: DRSConfig) -> int:
    """Number of groups kept: ceil((1-gamma) * G), at least 1."""
    g = num_groups(n_out, cfg.block)
    return max(1, int((1.0 - cfg.gamma) * g + 0.999999))


def group_scores(virtual: jax.Array, cfg: DRSConfig) -> jax.Array:
    """(..., N) virtual pre-activations -> (..., G) group scores."""
    g = virtual.shape[-1] // cfg.block
    v = virtual.reshape(virtual.shape[:-1] + (g, cfg.block))
    if cfg.score == "relu_sum":
        return jnp.sum(jax.nn.relu(v), axis=-1)
    if cfg.score == "abs_sum":
        return jnp.sum(jnp.abs(v), axis=-1)
    if cfg.score == "signed_sum":
        return jnp.sum(v, axis=-1)
    if cfg.score == "max":
        # argmax-retrieval proxy (serving logit DSG): the block's top
        # estimated activation, not its mass
        return jnp.max(v, axis=-1)
    raise ValueError(f"unknown score {cfg.score}")


def _topk_threshold(scores: jax.Array, k: int) -> jax.Array:
    """Per-row k-th largest score: (..., G) -> (..., 1)."""
    top = jax.lax.top_k(scores, k)[0]
    return top[..., k - 1:k]


def select_mask(scores: jax.Array, n_out: int, cfg: DRSConfig,
                ema_threshold: Optional[jax.Array] = None):
    """Group scores (..., G) -> (mask (..., G), new_ema or None).

    mask is float32 {0,1}.  Exactly-k per row only in "topk" mode; the
    shared/ema modes are thresholded (variable k per row) as in the paper.
    """
    k = keep_groups(n_out, cfg)
    g = scores.shape[-1]
    if k >= g:
        return jnp.ones_like(scores), ema_threshold
    if cfg.threshold_mode == "topk":
        thr = _topk_threshold(scores, k)
        mask = (scores >= thr).astype(jnp.float32)
        return mask, ema_threshold
    if cfg.threshold_mode == "shared":
        # Paper Appendix B / Fig. 9: threshold from the first sample, shared
        # across the rest of the mini-batch.  Rows are (..., G); "first
        # sample" = index 0 of the leading batch axis.
        flat = scores.reshape((-1, g))
        thr = _topk_threshold(flat[0:1], k)          # (1, 1)
        mask = (scores >= thr.reshape((1,) * (scores.ndim - 1) + (1,)))
        return mask.astype(jnp.float32), ema_threshold
    if cfg.threshold_mode == "ema":
        # Threshold carried across steps; current batch's exact top-k
        # threshold (mean over rows) feeds the EMA for the *next* step.
        thr_now = jnp.mean(_topk_threshold(scores, k))
        if ema_threshold is None:
            ema_threshold = thr_now
        thr = ema_threshold
        mask = (scores >= thr).astype(jnp.float32)
        new_ema = cfg.ema_decay * ema_threshold + (1 - cfg.ema_decay) * thr_now
        return mask, new_ema
    raise ValueError(f"unknown threshold_mode {cfg.threshold_mode}")


def drs_mask(fx: jax.Array, fw: jax.Array, cfg: DRSConfig,
             ema_threshold: Optional[jax.Array] = None):
    """Full DRS: f(X) (..., k) x f(W) (k, N) -> group mask (..., G).

    This is the cheap low-dimensional VMM the paper substitutes for the full
    one — cost O(T*k*N) instead of O(T*d*N), k << d.
    """
    virtual = jnp.einsum("...k,kn->...n", fx, fw)
    scores = group_scores(virtual, cfg)
    return select_mask(scores, fw.shape[-1], cfg, ema_threshold)


def expand_mask(mask: jax.Array, block: int) -> jax.Array:
    """Group mask (..., G) -> neuron mask (..., G*block)."""
    return jnp.repeat(mask, block, axis=-1)


def oracle_mask(pre_act: jax.Array, n_out: int, cfg: DRSConfig) -> jax.Array:
    """Paper Fig. 5(c) 'oracle' baseline: select on the TRUE pre-activations
    (requires the full VMM first — what DRS avoids)."""
    scores = group_scores(pre_act, cfg)
    mask, _ = select_mask(scores, n_out, cfg._replace(threshold_mode="topk"))
    return mask


def random_mask(key: jax.Array, batch_shape: tuple, n_out: int,
                cfg: DRSConfig) -> jax.Array:
    """Paper Fig. 5(c) 'random' baseline: keep k random groups per row."""
    g = num_groups(n_out, cfg.block)
    k = keep_groups(n_out, cfg)
    scores = jax.random.uniform(key, batch_shape + (g,))
    thr = _topk_threshold(scores, k)
    return (scores >= thr).astype(jnp.float32)
