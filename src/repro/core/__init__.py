"""repro.core — the DSG (Dynamic Sparse Graph) primary contribution.

Public surface:
  projection  — sparse random projection (Achlioptas ternary, JLL sizing)
  drs         — dimension-reduction search (virtual activations, top-k masks)
  masks       — mask algebra (group masks, sparse dataflow)
  double_mask — norm-compatible double-mask selection
  dsg_linear  — DSG FFN layers (mask / gather_shared modes) + DSGConfig
  stash       — compressed activation-stash accounting
"""
from repro.core.dsg_linear import DSGConfig  # noqa: F401
from repro.core.drs import DRSConfig  # noqa: F401
