"""Mask algebra for DSG sparse dataflow.

Masks are {0,1} float tensors at neuron-group granularity (..., G) or
expanded (..., N).  They are *constants* w.r.t. autodiff (paper Algorithm 1
treats Mask_k as data): we stop_gradient at creation so backward error
tensors are sparsified exactly where the forward was — `G_X <= Mask(...)`
falls out of differentiating the mask-multiply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def freeze(mask: jax.Array) -> jax.Array:
    return jax.lax.stop_gradient(mask)


def apply_expanded(x: jax.Array, group_mask: jax.Array, block: int) -> jax.Array:
    """x (..., G*block) * expand(group_mask (..., G)) without materializing
    the expanded mask separately (reshape-multiply keeps it fused)."""
    g = group_mask.shape[-1]
    xs = x.reshape(x.shape[:-1] + (g, block))
    y = xs * group_mask[..., None].astype(x.dtype)
    return y.reshape(x.shape)


def density(mask: jax.Array) -> jax.Array:
    """Fraction of ones — used by tests and the memory accounting."""
    return jnp.mean(mask)


def mask_overhead_bytes(shape: tuple, block: int) -> int:
    """Bitmask storage cost for the stash (paper: <2% of memory).  One bit
    per neuron group per row, byte-rounded."""
    rows = 1
    for s in shape[:-1]:
        rows *= s
    groups = shape[-1] // block
    return rows * ((groups + 7) // 8)
