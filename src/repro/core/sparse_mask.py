"""Structured group-CSR masks — the serving-side selection representation.

core/masks.py keeps selections as dense {0,1} tensors at neuron-group
granularity: right for training (the mask multiplies a tensor that was
computed anyway) but wrong for the serving hot path, where the point is to
NOT compute dropped groups.  The structured representation that turns a
mask into real compute savings is a per-row active-group index list
(group-level CSR): gathers over it are contiguous weight blocks, and a
host-side pattern update is an O(keep) integer write instead of a dense
tensor rebuild (Lasby et al., PAPERS.md; Graphcore popsparse / MindSpore
CSR, SNIPPETS.md).

A CSR row is (idx, count): `idx[:count]` are the active group indices in
ascending order, entries past `count` are zero-padded and must be ignored
(`csr_to_dense` and every consumer guard on `count`).  The row width is a
static *bound* bucketed to a power of two — the same trick as
`scheduler.live_page_bound` for the paged-attention walk — so the decode
step compiles at most log2(G)+1 variants as per-lane counts drift, not one
per count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def active_group_bound(max_count: int, n_groups: int) -> int:
    """Static CSR row width covering rows with up to `max_count` active
    groups: the count rounded up to a power of two, capped at G — decode
    compiles ≤ log2(G)+1 variants (mirrors scheduler.live_page_bound)."""
    need = max(1, int(max_count))
    return min(1 << (need - 1).bit_length(), n_groups)


def active_group_buckets(n_groups: int) -> tuple:
    """Every bound active_group_bound can return for G groups — the set a
    warm pass pre-compiles and traffic models enumerate."""
    return tuple(sorted({min(1 << i, n_groups)
                         for i in range(n_groups.bit_length() + 1)}))


def dense_to_csr(mask: jax.Array, bound: int):
    """Dense group mask (..., G) -> (idx (..., bound), counts (...,)).

    jit-friendly (static output shapes): sorting the key
    `where(active, g, G + g)` lists active group indices first, each side
    ascending, so the leading `bound` entries are exactly the active list
    when `bound` covers the row's count (rows with more active groups than
    `bound` are truncated — size the bound with active_group_bound).
    Padded entries are zeroed so a row's representation is canonical
    (tests compare them directly)."""
    g = mask.shape[-1]
    active = mask > 0
    key = jnp.where(active, jnp.arange(g), g + jnp.arange(g))
    order = jnp.argsort(key, axis=-1)[..., :bound].astype(jnp.int32)
    counts = jnp.minimum(jnp.sum(active, axis=-1), bound).astype(jnp.int32)
    valid = jnp.arange(bound) < counts[..., None]
    return jnp.where(valid, order, 0), counts


def csr_to_dense(idx: jax.Array, counts: jax.Array,
                 n_groups: int) -> jax.Array:
    """(idx (..., K), counts (...,)) -> dense {0,1} float32 mask (..., G).
    Padded entries (positions >= count) are ignored, whatever they hold."""
    k = idx.shape[-1]
    valid = (jnp.arange(k) < counts[..., None]).astype(jnp.float32)
    oh = jax.nn.one_hot(idx, n_groups, dtype=jnp.float32)
    return jnp.minimum(jnp.einsum("...kg,...k->...g", oh, valid), 1.0)


def csr_rows(shape: tuple) -> int:
    rows = 1
    for s in shape:
        rows *= s
    return rows


def csr_overhead_bytes(batch_shape: tuple, bound: int,
                       idx_bytes: int = 4, count_bytes: int = 4) -> int:
    """Storage cost of the CSR pattern state: `bound` int32 indices plus
    one int32 count per row.  Compare masks.mask_overhead_bytes (1 bit per
    group per row): the bitmask is smaller at rest, but the CSR list is
    what the gather walks and what the host rewrites in O(keep) per
    refresh — the representation is priced for the decode loop, not for
    the stash."""
    return csr_rows(batch_shape) * (bound * idx_bytes + count_bytes)
