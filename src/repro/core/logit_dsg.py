"""Serving-time DSG on the LM head (beyond-paper, DESIGN.md §7.6).

At decode time the vocab projection (d -> V, V up to 202k here) dominates
per-token FLOPs for small batches.  Greedy/top-p sampling only needs the
high logits, so the paper's machinery applies directly: DRS estimates the
logit blocks from f(x) @ f(W_head), the top (1-gamma) blocks are gathered,
and exact logits are computed only for the survivors.  Masked-out vocab
blocks are reported as -inf (they cannot win sampling among survivors).

Training keeps the full head (the softmax normalizer needs all logits).
Exactness caveat (documented): greedy decoding is exact whenever the true
argmax block is selected — the test measures the JLL-governed hit rate.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import drs, projection
from repro.core.dsg_linear import DSGConfig

NEG = -1e30


def init_logit_dsg(key: jax.Array, w_head: jax.Array,
                   cfg: DSGConfig) -> dict:
    """w_head (d, V) -> {'r': (k, d), 'fw': (k, V)}."""
    d, v = w_head.shape
    k = projection.jll_dim(d, v, cfg.eps)
    r = projection.make_projection(key, k, d, dtype=w_head.dtype)
    return {"r": r, "fw": projection.project(r, w_head)}


def dsg_logits(x: jax.Array, w_head: jax.Array, state: dict,
               cfg: DSGConfig, per_request: bool = True
               ) -> Tuple[jax.Array, jax.Array]:
    """x (B, d) -> (logits (B, V) with -inf on skipped blocks, block mask).

    per_request=True selects blocks independently per row (the default:
    a decode batch serves unrelated requests whose argmax blocks are
    disjoint — a batch-shared selection caps the greedy hit rate at
    roughly (1-gamma) for diverse batches, measured in
    tests/test_serving.py).  Block scores use the max estimated logit in
    the block (argmax-retrieval proxy)."""
    b, d = x.shape
    v = w_head.shape[1]
    blk = cfg.block
    g = v // blk
    keep = max(1, int((1.0 - cfg.gamma) * g + 0.999999))

    fx = projection.project_rows(state["r"], x)
    virtual = jnp.einsum("bk,kv->bv", fx, state["fw"])
    scores = drs.group_scores(virtual, cfg.drs_cfg()._replace(
        score="max"))                                      # (B, G)
    w3 = w_head.reshape(d, g, blk).transpose(1, 0, 2)      # (G, d, blk)

    if per_request:
        _, idx = jax.lax.top_k(scores, keep)               # (B, keep)
        idx = jnp.sort(idx, axis=-1)
        w_sel = w3[idx]                                    # (B, keep, d, blk)
        part = jnp.einsum("bd,bkdc->bkc", x, w_sel)
        logits = jnp.full((b, g, blk), NEG, part.dtype)
        logits = logits.at[jnp.arange(b)[:, None], idx].set(part)
        mask = jnp.zeros((b, g), jnp.float32).at[
            jnp.arange(b)[:, None], idx].set(1.0)
        return logits.reshape(b, v), mask

    shared = scores.max(axis=0)                            # batch-shared
    _, idx = jax.lax.top_k(shared, keep)
    idx = jnp.sort(idx)
    part = jnp.einsum("bd,kdc->bkc", x, w3[idx])
    logits = jnp.full((b, g, blk), NEG, part.dtype)
    logits = logits.at[:, idx].set(part)
    mask = jnp.broadcast_to(
        jnp.zeros((g,), jnp.float32).at[idx].set(1.0), (b, g))
    return logits.reshape(b, v), mask


def flops_saving(v: int, d: int, cfg: DSGConfig) -> float:
    """Fraction of head FLOPs avoided (minus the DRS search cost)."""
    k = projection.jll_dim(d, v, cfg.eps)
    full = d * v
    search = k * d + k * v
    kept = (1.0 - cfg.gamma) * full
    return 1.0 - (search + kept) / full
