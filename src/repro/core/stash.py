"""Activation-stash accounting — the paper's representational-cost model.

The paper compresses stashed activations with zero-value compression (ZVC)
between forward and backward.  On TPU the user-level analogue is (a) the
gather_shared path, whose stash is physically (1-gamma) of the dense one,
and (b) compressed accounting for the mask path, where a real deployment
stores `h * mask` in a compacted buffer (value stream + bitmask) via a
custom DMA/kernel.  These helpers compute the analytic sizes used by
benchmarks/bench_memory.py (reproducing Fig. 6's methodology) and by tests.
"""
from __future__ import annotations

from typing import Iterable, Tuple

import numpy as np

from repro.core.masks import mask_overhead_bytes


def dense_stash_bytes(shape: Tuple[int, ...], dtype_bytes: int = 2) -> int:
    return int(np.prod(shape)) * dtype_bytes


def dsg_stash_bytes(shape: Tuple[int, ...], gamma: float, block: int,
                    dtype_bytes: int = 2) -> int:
    """Compressed stash: kept values + group bitmask.  `shape` is the dense
    activation shape with the neuron dim last."""
    dense = dense_stash_bytes(shape, dtype_bytes)
    kept = int(dense * (1.0 - gamma))
    return kept + mask_overhead_bytes(shape, block)


def training_footprint(layer_shapes: Iterable[Tuple[int, ...]], gamma: float,
                       block: int, param_bytes: int,
                       dtype_bytes: int = 2) -> dict:
    """Total training-memory model: params + all stashed activations
    (training stashes every layer's activations for backward).  Returns the
    dense and DSG-compressed totals and the compression ratio — the paper's
    Fig. 6(a) quantities."""
    dense_act = sum(dense_stash_bytes(s, dtype_bytes) for s in layer_shapes)
    dsg_act = sum(dsg_stash_bytes(s, gamma, block, dtype_bytes)
                  for s in layer_shapes)
    dense_total = param_bytes + dense_act
    dsg_total = param_bytes + dsg_act
    return {
        "dense_total": dense_total,
        "dsg_total": dsg_total,
        "dense_activations": dense_act,
        "dsg_activations": dsg_act,
        "ratio_total": dense_total / max(dsg_total, 1),
        "ratio_activations": dense_act / max(dsg_act, 1),
    }


def inference_footprint(layer_shapes: Iterable[Tuple[int, ...]], gamma: float,
                        block: int, param_bytes: int,
                        dtype_bytes: int = 2) -> dict:
    """Inference stores params + the single largest layer activation
    (paper §3.3)."""
    shapes = list(layer_shapes)
    dense_act = max(dense_stash_bytes(s, dtype_bytes) for s in shapes)
    dsg_act = max(dsg_stash_bytes(s, gamma, block, dtype_bytes)
                  for s in shapes)
    return {
        "dense_total": param_bytes + dense_act,
        "dsg_total": param_bytes + dsg_act,
        "ratio_total": (param_bytes + dense_act) / max(param_bytes + dsg_act, 1),
    }
