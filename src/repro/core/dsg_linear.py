"""DSG-sparsified linear/FFN layers — the paper's technique as composable ops.

Three execution modes (DESIGN.md §2, §7):

  * "dense"         — baseline, no DSG.
  * "mask"          — paper-faithful: DRS selects neuron groups per token;
                      the full matmul runs and the mask multiplies the
                      output.  XLA cannot skip dynamic per-token columns, so
                      HLO FLOPs are unchanged — the compute saving at this
                      granularity is realized by the Pallas kernel
                      (kernels/dsg_matmul.py); the *memory* saving (compact
                      stash for backward) is realized here via the masked
                      stash in the custom-vjp path.
  * "gather_shared" — beyond-paper TPU adaptation: one selection shared by
                      all tokens in the (per-device) batch, computed from
                      batch-summed group scores, optionally balanced across
                      `n_chunks` contiguous shard-aligned chunks of the
                      output dim.  The kept weight blocks are gathered once
                      and the matmul shrinks to (1-gamma) of the columns —
                      the FLOP reduction is visible to XLA (and the
                      roofline).

Weights layout: w_gate/w_up are (d, F), w_down is (F, d); the DSG group dim
is F split into G = F/block groups.  Sharding: F dim over the "model" mesh
axis; with n_chunks = number of model shards the gather stays shard-local.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import drs, masks, projection


class DSGConfig(NamedTuple):
    enabled: bool = False
    gamma: float = 0.5            # fraction of neuron groups dropped
    eps: float = 0.5              # JLL epsilon -> projection dim k
    block: int = 128              # neuron-group width
    threshold_mode: str = "topk"  # "topk" | "shared" | "ema"
    score: str = "relu_sum"
    mode: str = "mask"            # "mask" | "gather_shared"
    n_chunks: int = 1             # balanced per-chunk selection (shard-aligned)
    refresh_every: int = 50       # f(W) refresh period (paper: 50)

    def drs_cfg(self) -> drs.DRSConfig:
        return drs.DRSConfig(gamma=self.gamma, block=self.block,
                             threshold_mode=self.threshold_mode,
                             score=self.score)


def proj_dim(d: int, n_out: int, cfg: DSGConfig) -> int:
    return projection.jll_dim(d, n_points=n_out + 1, eps=cfg.eps)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_swiglu(key: jax.Array, d: int, f: int, dtype=jnp.float32) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    sc_in = 1.0 / math.sqrt(d)
    sc_out = 1.0 / math.sqrt(f)
    return {
        "w_gate": (jax.random.normal(kg, (d, f)) * sc_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (d, f)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (f, d)) * sc_out).astype(dtype),
    }


def init_gelu_ffn(key: jax.Array, d: int, f: int, dtype=jnp.float32) -> dict:
    ku, kd = jax.random.split(key)
    return {
        "w_up": (jax.random.normal(ku, (d, f)) / math.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(kd, (f, d)) / math.sqrt(f)).astype(dtype),
    }


def init_dsg_state(key: jax.Array, d: int, f: int, cfg: DSGConfig,
                   w_search: jax.Array, dtype=jnp.float32) -> dict:
    """Non-trainable DSG buffers: projection matrix R and projected search
    weights f(W).  f(W) is refreshed every cfg.refresh_every steps by the
    training loop (refresh_fw), matching the paper's amortization."""
    k = proj_dim(d, f, cfg)
    r = projection.make_projection(key, k, d, dtype=dtype)
    fw = projection.project(r, w_search.astype(dtype))
    return {"r": r, "fw": fw}


def refresh_fw(state: dict, w_search: jax.Array) -> dict:
    return {"r": state["r"],
            "fw": projection.project(state["r"], w_search.astype(state["r"].dtype))}


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------

def drs_group_mask(x: jax.Array, state: dict, cfg: DSGConfig) -> jax.Array:
    """Per-token group mask (..., G) from the dimension-reduction search."""
    fx = projection.project_rows(state["r"], x)
    mask, _ = drs.drs_mask(fx, state["fw"], cfg.drs_cfg())
    return masks.freeze(mask)


def shared_topk_indices(x: jax.Array, state: dict, cfg: DSGConfig,
                        f: int) -> jax.Array:
    """Batch-shared selection ("gather_shared"): sum group scores over all
    token rows, then per-chunk top-k so the gather is shard-local and
    load-balanced.  Returns sorted kept-group indices (K',)."""
    fx = projection.project_rows(state["r"], x)
    virtual = jnp.einsum("...k,kn->...n", fx, state["fw"])
    scores = drs.group_scores(virtual, cfg.drs_cfg())
    scores = scores.reshape((-1, scores.shape[-1])).sum(axis=0)  # (G,)
    g = scores.shape[0]
    keep_total = drs.keep_groups(f, cfg.drs_cfg())
    n_chunks = max(1, cfg.n_chunks)
    if g % n_chunks != 0:
        n_chunks = 1
    per_chunk = max(1, keep_total // n_chunks)
    chunked = scores.reshape(n_chunks, g // n_chunks)
    _, local_idx = jax.lax.top_k(chunked, per_chunk)         # (C, kc)
    base = (jnp.arange(n_chunks) * (g // n_chunks))[:, None]
    idx = (local_idx + base).reshape(-1)
    return jax.lax.stop_gradient(jnp.sort(idx))


# ---------------------------------------------------------------------------
# forward paths
# ---------------------------------------------------------------------------

def swiglu_dense(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def swiglu_dsg_mask(p: dict, x: jax.Array, state: dict,
                    cfg: DSGConfig) -> jax.Array:
    """Paper-faithful per-token masked SwiGLU.  The mask zeroes whole neuron
    groups after the nonlinearity; backward error through w_down rows and
    gate/up columns of dropped groups is exactly zero (Algorithm 1)."""
    mask = drs_group_mask(x, state, cfg)                    # (..., G)
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g) * u
    h = masks.apply_expanded(h, mask, cfg.block)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def swiglu_dsg_gather(p: dict, x: jax.Array, state: dict,
                      cfg: DSGConfig) -> jax.Array:
    """Batch-shared gathered SwiGLU: computes only kept groups.

    FLOPs ~ (1-gamma) * dense; weight gather traffic ~ (1-gamma) of the
    weight bytes (HBM-side win too)."""
    d, f = p["w_gate"].shape
    b = cfg.block
    gct = f // b
    idx = shared_topk_indices(x, state, cfg, f)             # (K',)
    # leading-axis gathers: a middle-axis take gets rewritten by XLA into
    # a one-hot dot (observed: +3.5x HLO FLOPs, EXPERIMENTS.md §Perf A5);
    # transposing first keeps it a real gather.
    wg = p["w_gate"].reshape(d, gct, b).transpose(1, 0, 2)[idx]  # (K', d, b)
    wu = p["w_up"].reshape(d, gct, b).transpose(1, 0, 2)[idx]
    wd = p["w_down"].reshape(gct, b, d)[idx]                     # (K', b, d)
    g = jnp.einsum("...d,kdb->...kb", x, wg)
    u = jnp.einsum("...d,kdb->...kb", x, wu)
    h = jax.nn.silu(g) * u
    return jnp.einsum("...kb,kbd->...d", h, wd)


def swiglu_dsg_gather_sharded(p: dict, x: jax.Array, state: dict,
                              cfg: DSGConfig) -> jax.Array:
    """gather_shared under TP (EXPERIMENTS.md §Perf A8): each 'model' shard
    top-ks its LOCAL groups and gathers its LOCAL weight blocks inside
    shard_map — no cross-shard gather (the A5 failure mode: XLA rewrote a
    gather across the sharded F axis into a one-hot dot / weight
    all-gather).  Selection is balanced per shard by construction (the
    n_chunks semantics with chunks == shards), and the FLOP reduction
    ~ (1-gamma) is visible in the compiled HLO."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel import context as pctx

    ctx = pctx.current()
    mesh, ba = ctx.mesh, ctx.ax.batch
    d = p["w_gate"].shape[0]
    blk = cfg.block
    drs_cfg = cfg.drs_cfg()

    def body(x_l, wg, wu, wd, r, fw):
        f_loc = wg.shape[1]
        g_loc = f_loc // blk
        keep = max(1, int((1.0 - cfg.gamma) * g_loc + 0.999999))
        fx = projection.project_rows(r, x_l)
        virtual = jnp.einsum("...k,kn->...n", fx, fw)
        scores = drs.group_scores(virtual, drs_cfg)
        scores = scores.reshape(-1, g_loc).sum(0)              # (G_loc,)
        _, idx = jax.lax.top_k(scores, keep)
        idx = jax.lax.stop_gradient(jnp.sort(idx))
        wg3 = wg.reshape(d, g_loc, blk).transpose(1, 0, 2)[idx]
        wu3 = wu.reshape(d, g_loc, blk).transpose(1, 0, 2)[idx]
        wd3 = wd.reshape(g_loc, blk, d)[idx]
        g = jnp.einsum("...d,kdb->...kb", x_l, wg3)
        u = jnp.einsum("...d,kdb->...kb", x_l, wu3)
        h = jax.nn.silu(g) * u
        y = jnp.einsum("...kb,kbd->...d", h, wd3)
        return jax.lax.psum(y, "model")

    nd = (None,) * (x.ndim - 1)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(ba, *nd), P(None, "model"), P(None, "model"),
                  P("model", None), P(), P(None, "model")),
        out_specs=P(ba, *nd),
    )(x, p["w_gate"], p["w_up"], p["w_down"], state["r"], state["fw"])


# ---------------------------------------------------------------------------
# group-CSR serving paths (core/sparse_mask.py representation)
# ---------------------------------------------------------------------------

def swiglu_csr_masked(p: dict, x: jax.Array, idx: jax.Array,
                      counts: jax.Array, *, block: int) -> jax.Array:
    """Masked-dense reference for a per-lane CSR selection: expand the
    index list back to a dense group mask and run the full matmuls — zero
    compute saving, the bitwise baseline the gather/kernel paths are
    pinned against.  x (B, S, d), idx (B, K), counts (B,)."""
    from repro.core import sparse_mask
    f = p["w_gate"].shape[1]
    mask = sparse_mask.csr_to_dense(idx, counts, f // block)   # (B, G)
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g) * u
    h = masks.apply_expanded(h, masks.freeze(mask[:, None, :]), block)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def swiglu_csr_gather(p: dict, x: jax.Array, idx: jax.Array,
                      counts: jax.Array, *, block: int) -> jax.Array:
    """XLA fallback: contract only the leading K = active-group bound
    blocks per lane (the paged-attention bounded-gather trick — K is a
    static pow2 bucket, so FLOPs scale with the bound, not F).  Per-lane
    patterns force a per-lane weight-block gather (B, K, d, block); the
    CSR Pallas kernel avoids materializing it — this path is the
    non-Mosaic fallback.  Padded slots (>= counts) are zeroed before the
    down-projection, so the result matches swiglu_csr_masked."""
    d, f = p["w_gate"].shape
    b = idx.shape[0]
    k = idx.shape[-1]
    # flat column gather: expand the group list to neuron columns and
    # take along the weights' LAST axis (rows for w_down).  Copy volume
    # is B * K * block columns — it scales with the bound, unlike a
    # transpose-first group gather, whose (d, G, block) -> (G, d, block)
    # shuffle re-copies the FULL weight every decode step.  (Middle-axis
    # takes are still the A5 trap — XLA turns them into one-hot dots.)
    cols = (idx[..., None] * block
            + jnp.arange(block)).reshape(b, k * block)         # (B, KB)
    wg = jnp.take(p["w_gate"], cols, axis=1)                   # (d, B, KB)
    wu = jnp.take(p["w_up"], cols, axis=1)
    wd = jnp.take(p["w_down"], cols, axis=0)                   # (B, KB, d)
    g = jnp.einsum("bsd,dbm->bsm", x, wg)
    u = jnp.einsum("bsd,dbm->bsm", x, wu)
    h = jax.nn.silu(g) * u                                     # (B, S, KB)
    valid = (jnp.arange(k) < counts[:, None]).astype(h.dtype)  # (B, K)
    h = h * jnp.repeat(valid, block, axis=-1)[:, None, :]
    return jnp.einsum("bsm,bmd->bsd", h, wd)


def swiglu_csr(p: dict, x: jax.Array, idx: jax.Array, counts: jax.Array,
               *, block: int, apply: str = "auto") -> jax.Array:
    """Group-CSR SwiGLU dispatch (models/transformer._ffn_apply serving
    path).  `apply`: "dense" masked-dense reference, "xla" bounded
    gather, "kernel" Pallas index-list walk (kernels/dsg_ffn.dsg_ffn_csr,
    decode only: S == 1), "auto" = kernel where Mosaic compiles it."""
    b, s, d = x.shape
    if apply == "auto":
        apply = ("kernel" if jax.default_backend() == "tpu" and s == 1
                 else "xla")
    if apply == "dense":
        return swiglu_csr_masked(p, x, idx, counts, block=block)
    if apply == "xla":
        return swiglu_csr_gather(p, x, idx, counts, block=block)
    if apply != "kernel":
        raise ValueError(f"unknown CSR FFN apply mode {apply!r}")
    if s != 1:
        raise ValueError(
            f"CSR FFN kernel is a decode step (one token per lane), got "
            f"S={s}; use apply='xla' for multi-token rows")
    from repro.kernels import ops
    y = ops.dsg_ffn_csr(x[:, 0], p["w_gate"], p["w_up"], p["w_down"],
                        idx, counts, block=block)
    return y[:, None, :]


def swiglu_ffn(p: dict, x: jax.Array, state: Optional[dict],
               cfg: DSGConfig) -> jax.Array:
    if not cfg.enabled or state is None:
        return swiglu_dense(p, x)
    if cfg.mode == "gather_shared":
        from repro.parallel import context as pctx
        ctx = pctx.current()
        f = p["w_gate"].shape[1]
        if (ctx is not None and ctx.n_model > 1
                and f % (ctx.n_model * cfg.block) == 0):
            return swiglu_dsg_gather_sharded(p, x, state, cfg)
        return swiglu_dsg_gather(p, x, state, cfg)
    return swiglu_dsg_mask(p, x, state, cfg)


def gelu_dense(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_up"]))
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def gelu_ffn(p: dict, x: jax.Array, state: Optional[dict],
             cfg: DSGConfig) -> jax.Array:
    """GELU FFN (whisper) with DSG on the up projection."""
    if not cfg.enabled or state is None:
        return gelu_dense(p, x)
    if cfg.mode == "gather_shared":
        d, f = p["w_up"].shape
        b = cfg.block
        idx = shared_topk_indices(x, state, cfg, f)
        wu = p["w_up"].reshape(d, f // b, b)[:, idx]
        wd = p["w_down"].reshape(f // b, b, d)[idx]
        h = jax.nn.gelu(jnp.einsum("...d,dkb->...kb", x, wu))
        return jnp.einsum("...kb,kbd->...d", h, wd)
    mask = drs_group_mask(x, state, cfg)
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_up"]))
    h = masks.apply_expanded(h, mask, cfg.block)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def search_weight(p: dict) -> jax.Array:
    """Which weight the DRS estimates against: the gate path if present
    (SiLU argument decides the activation magnitude), else the up path."""
    return p["w_gate"] if "w_gate" in p else p["w_up"]
