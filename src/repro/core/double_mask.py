"""Double-mask selection (paper §2.3) — norm compatibility.

Normalization layers fuse information across elements and turn exact zeros
into small non-zeros, destroying the sparsity DRS created.  The paper's fix:
apply the SAME selection mask again after the norm.  Correct because the
norm is monotone per-channel (scale+shift does not reorder activations), so
the masked-out neurons are still the removable ones.

The paper's case is BatchNorm ('CONV/FC -> ReLU -> BN' after their
reordering).  We generalize to the norms that appear in our stacks:
  * BatchNorm  — paper-native CNN/MLP configs (train-mode batch stats).
  * LayerNorm / RMSNorm — post-norm transformer variants: mean/RMS are
    computed across the channel dim, so zeros densify exactly as with BN.
Pre-norm transformer blocks do not need a double mask (the norm precedes the
masked linear); the single post-selection mask already leaves the residual
stream sparse.  See DESIGN.md §2.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import masks


def batch_norm_train(x: jax.Array, scale: jax.Array, bias: jax.Array,
                     axis: int = 0, eps: float = 1e-5) -> jax.Array:
    """Training-mode BN over the batch axis (per-feature stats)."""
    mean = jnp.mean(x, axis=axis, keepdims=True)
    var = jnp.var(x, axis=axis, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def double_mask(norm_fn: Callable[[jax.Array], jax.Array],
                x: jax.Array, group_mask: jax.Array, block: int) -> jax.Array:
    """y = Mask( norm( Mask(x) ) ) — the paper's Fig. 2(c) dataflow.

    `group_mask` is the (..., G) selection mask produced by DRS for this
    layer; it is applied at group granularity both before and after the
    norm, restoring a fully sparse dataflow."""
    m = masks.freeze(group_mask)
    pre = masks.apply_expanded(x, m, block)
    post = norm_fn(pre)
    return masks.apply_expanded(post, m, block)


def single_mask(norm_fn: Callable[[jax.Array], jax.Array],
                x: jax.Array, group_mask: jax.Array, block: int) -> jax.Array:
    """Ablation baseline (paper Fig. 5(e) middle case): mask only before the
    norm — the norm's output is dense again."""
    m = masks.freeze(group_mask)
    return norm_fn(masks.apply_expanded(x, m, block))
