"""Paper-native CONV path: DSG on convolutions via im2col (paper §2.2).

The paper converts each CONV layer to VMM form: every output position is
a sliding-window row X_i (n_CRS = C*R*S) against the filter matrix
(n_CRS, n_K); DRS estimates the n_K output activations per window and
masks non-critical filters per position.  This module reproduces that
formulation exactly (used by the paper-fidelity tests and the CNN-era
benchmarks); the transformer FFN path in dsg_linear.py is the
production-scale analogue (DESIGN.md §2).

Includes the double-mask BN hookup: CONV -> ReLU(masked) -> BN -> same
mask (paper Fig 2(c), with the paper's CONV-ReLU-BN reordering §2.2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import double_mask as dm
from repro.core import drs, masks, projection
from repro.core.dsg_linear import DSGConfig


def im2col(x: jax.Array, rs: Tuple[int, int], padding: str = "SAME"):
    """x (B, H, W, C) -> patches (B, H', W', C*R*S)."""
    r, s = rs
    pad = ((r // 2, (r - 1) // 2), (s // 2, (s - 1) // 2)) \
        if padding == "SAME" else ((0, 0), (0, 0))
    xp = jnp.pad(x, ((0, 0), pad[0], pad[1], (0, 0)))
    b, hp, wp, c = xp.shape
    ho = hp - r + 1
    wo = wp - s + 1
    idx_h = jnp.arange(ho)[:, None] + jnp.arange(r)[None, :]
    idx_w = jnp.arange(wo)[:, None] + jnp.arange(s)[None, :]
    patches = xp[:, idx_h][:, :, :, idx_w]        # (B, H', R, W', S, C)
    patches = jnp.moveaxis(patches, 2, 3)         # (B, H', W', R, S, C)
    return patches.reshape(b, ho, wo, r * s * c)


def init_conv_dsg(key: jax.Array, c_in: int, rs: Tuple[int, int],
                  n_k: int, cfg: DSGConfig):
    """Filter matrix (CRS, K) + DSG state (R projection over CRS, f(W))."""
    kw, kr = jax.random.split(key)
    crs = rs[0] * rs[1] * c_in
    w = jax.random.normal(kw, (crs, n_k)) / jnp.sqrt(crs)
    k = projection.jll_dim(crs, n_k, cfg.eps)
    r = projection.make_projection(kr, k, crs)
    return {"w": w, "r": r, "fw": projection.project(r, w)}


def conv2d_dsg(p: dict, x: jax.Array, rs: Tuple[int, int], cfg: DSGConfig,
               bn_scale: Optional[jax.Array] = None,
               bn_bias: Optional[jax.Array] = None,
               mask_mode: str = "double"):
    """DSG convolution: im2col -> DRS per sliding window -> masked VMM
    -> ReLU -> (optional BN with double mask).

    x (B, H, W, C) -> (y (B, H', W', K), group_mask)."""
    patches = im2col(x, rs)                               # (B,H',W',CRS)
    b, ho, wo, crs = patches.shape
    rows = patches.reshape(-1, crs)
    if cfg.enabled:
        fx = projection.project_rows(p["r"], rows)
        gmask, _ = drs.drs_mask(fx, p["fw"], cfg.drs_cfg())
        gmask = masks.freeze(gmask)
    else:
        gmask = None
    pre = rows @ p["w"]                                   # (rows, K)
    act = jax.nn.relu(pre)
    if gmask is not None:
        act = masks.apply_expanded(act, gmask, cfg.block)
    if bn_scale is not None:
        def bn(z):
            return dm.batch_norm_train(z, bn_scale, bn_bias)
        if gmask is None:
            act = bn(act)
        elif mask_mode == "double":
            act = dm.double_mask(bn, act, gmask, cfg.block)
        else:
            act = dm.single_mask(bn, act, gmask, cfg.block)
    y = act.reshape(b, ho, wo, -1)
    return y, gmask


def conv2d_ref(w: jax.Array, x: jax.Array, rs: Tuple[int, int]):
    """lax.conv oracle for the unmasked path (tests)."""
    r, s = rs
    c_in = x.shape[-1]
    n_k = w.shape[-1]
    kernel = w.reshape(r, s, c_in, n_k)
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
