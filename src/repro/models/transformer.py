"""Decoder-only transformer assembly (dense / MoE / VLM families).

Layers are stacked (L, ...) pytrees scanned with lax.scan — HLO size is
depth-independent (required for the 512-device dry-run compiles) and remat
wraps the scan body.  The DSG state mirrors the layer stack: one shared
projection R (d -> k) plus per-layer f(W) buffers refreshed by the training
loop every cfg.dsg.refresh_every steps.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.core import dsg_linear as dl
from repro.core import projection
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import embed_init, norm_apply, norm_init


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    ka, kf = jax.random.split(key)
    dt = _dtype(cfg)
    p = {
        "ln_attn": norm_init(cfg.norm, cfg.d_model, dt),
        "attn": attn.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                    cfg.head_dim, dt),
        "ln_ffn": norm_init(cfg.norm, cfg.d_model, dt),
    }
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(kf, cfg.d_model, cfg.moe_experts,
                                    cfg.moe_d_ff, cfg.moe_shared, dt)
    else:
        p["ffn"] = dl.init_swiglu(kf, cfg.d_model, cfg.d_ff, dt)
    return p


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    dt = _dtype(cfg)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "layers": layers,
        "ln_final": norm_init(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                        / math.sqrt(cfg.d_model)).astype(dt)
    return p


def init_dsg(key: jax.Array, params: dict, cfg: ModelConfig) -> Optional[dict]:
    """DSG buffers: shared R + per-layer f(W) stacks (DESIGN.md §5)."""
    if not cfg.dsg.enabled:
        return None
    dt = _dtype(cfg)
    if cfg.is_moe:
        fe = cfg.moe_d_ff
        k = dl.proj_dim(cfg.d_model, fe, cfg.dsg)
        r = projection.make_projection(key, k, cfg.d_model, dtype=dt)
        st = {"r": r}
        st["fw_experts"] = jnp.einsum(
            "kd,ledf->lekf", r, params["layers"]["moe"]["w_gate"])
        if cfg.moe_shared > 0:
            st["fw_shared"] = jnp.einsum(
                "kd,ldf->lkf", r, params["layers"]["moe"]["shared"]["w_gate"])
        return st
    k = dl.proj_dim(cfg.d_model, cfg.d_ff, cfg.dsg)
    r = projection.make_projection(key, k, cfg.d_model, dtype=dt)
    fw = jnp.einsum("kd,ldf->lkf", r, params["layers"]["ffn"]["w_gate"])
    return {"r": r, "fw": fw}


def refresh_dsg(dsg: dict, params: dict, cfg: ModelConfig) -> dict:
    """Recompute f(W) from current weights (paper: every 50 steps)."""
    if dsg is None:
        return None
    out = {"r": dsg["r"]}
    if cfg.is_moe:
        out["fw_experts"] = jnp.einsum(
            "kd,ledf->lekf", dsg["r"], params["layers"]["moe"]["w_gate"])
        if "fw_shared" in dsg:
            out["fw_shared"] = jnp.einsum(
                "kd,ldf->lkf", dsg["r"],
                params["layers"]["moe"]["shared"]["w_gate"])
    else:
        out["fw"] = jnp.einsum("kd,ldf->lkf", dsg["r"],
                               params["layers"]["ffn"]["w_gate"])
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_dsg(dsg: Optional[dict], cfg: ModelConfig):
    """Slice the per-layer DSG leaves for scan xs (r stays shared)."""
    if dsg is None:
        return None
    return {k: v for k, v in dsg.items() if k != "r"}


def _ffn_apply(p: dict, dsg_l: Optional[dict], r: Optional[jax.Array],
               x: jax.Array, cfg: ModelConfig, mesh, batch_axes,
               csr_l: Optional[dict] = None):
    """FFN or MoE with DSG; returns (y, aux).

    csr_l: this layer's group-CSR selection {'idx': (B, K),
    'counts': (B,)} from the serving DSG runtime — when present the FFN
    contracts only the listed groups (core/dsg_linear.swiglu_csr: masked
    dense reference, bounded XLA gather, or the CSR Pallas kernel per
    cfg.dsg_ffn_apply) instead of running DRS online per token."""
    if csr_l is not None:
        if cfg.is_moe:
            raise NotImplementedError(
                "group-CSR serving selection targets the dense-FFN "
                "family; MoE experts are already conditional compute")
        y = dl.swiglu_csr(p["ffn"], x, csr_l["idx"], csr_l["counts"],
                          block=cfg.dsg.block, apply=cfg.dsg_ffn_apply)
        return y, jnp.float32(0.0)
    if cfg.is_moe:
        dsg_state = None
        if dsg_l is not None:
            dsg_state = {"r": r, "fw_experts": dsg_l["fw_experts"]}
            if "fw_shared" in dsg_l:
                dsg_state["shared"] = {"r": r, "fw": dsg_l["fw_shared"]}
        return moe_mod.moe_ffn(
            p["moe"], x, n_experts=cfg.moe_experts, top_k=cfg.moe_topk,
            capacity_factor=cfg.moe_capacity_factor, dsg=cfg.dsg,
            dsg_state=dsg_state, mesh=mesh, batch_axes=batch_axes,
            aux_kind=cfg.moe_aux)
    st = {"r": r, "fw": dsg_l["fw"]} if dsg_l is not None else None
    return dl.swiglu_ffn(p["ffn"], x, st, cfg.dsg), jnp.float32(0.0)


def _drs_scores(h: jax.Array, r: jax.Array, fw: jax.Array,
                cfg: ModelConfig) -> jax.Array:
    """DRS group scores of the FFN input h (B, S, d) -> (B, S, G), on
    device through the Pallas search kernels (kernels/drs_search.py):
    f(h) = h @ R^T, then fused virtual-matmul + relu-sum group reduce.
    The serving DSG runtime reads these back once per refresh window to
    rewrite its CSR patterns (host bookkeeping lags the kernel, like the
    paged page-table mirror)."""
    from repro.kernels import ops as kernel_ops
    b, s, d = h.shape
    m = b * s
    bm = m if m % 128 else 128          # kernels assert m % bm == 0
    f = fw.shape[-1]
    bf = f if f % 512 else 512
    fx = kernel_ops.drs_project(h.reshape(m, d).astype(r.dtype), r, bm=bm)
    scores = kernel_ops.drs_scores(fx, fw, block=cfg.dsg.block, bm=bm,
                                   bf=bf)
    return scores.reshape(b, s, f // cfg.dsg.block)


def _block(p: dict, dsg_l, r, x, cfg: ModelConfig, q_pos, cache, cache_pos,
           page_table, live_pages, mesh, batch_axes, csr_l=None,
           collect_scores: bool = False):
    from repro.parallel import context as pctx

    def boundary(t):
        """Perf lever (EXPERIMENTS.md §Perf A1/A3): force the TP branch
        psum to land at the bf16 branch boundary.  A sharding constraint
        alone does NOT do it (partial-sum state is orthogonal to sharding
        and GSPMD defers the all-reduce past the fp32 cast inside the next
        norm — 2x wire bytes); an optimization barrier is a wall the
        partitioner cannot defer a pending reduction across."""
        if cfg.branch_constrain:
            return jax.lax.optimization_barrier(t)
        return t

    if cfg.seq_sharded_residual:
        # Megatron-SP: residual stream (== the remat stash) seq-sharded
        ba = pctx.batch_axes()
        x = pctx.constrain(x, ba, "model", None)
    h = norm_apply(cfg.norm, p["ln_attn"], x)
    a, new_cache = attn.self_attention(
        p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        rope_theta=cfg.rope_theta, q_pos=q_pos, causal=True,
        window=cfg.window, cache=cache, cache_pos=cache_pos,
        page_table=page_table, live_pages=live_pages,
        paged_kernel=cfg.paged_attn_kernel, shard=cfg.attn_shard,
        bf16_scores=cfg.attn_bf16_scores)
    x = x + boundary(a)
    h = norm_apply(cfg.norm, p["ln_ffn"], x)
    scores = None
    if collect_scores:
        scores = _drs_scores(h, r, dsg_l["fw"], cfg)
    f, aux = _ffn_apply(p, dsg_l, r, h, cfg, mesh, batch_axes, csr_l)
    x = x + boundary(f)
    if cfg.seq_sharded_residual:
        x = pctx.constrain(x, pctx.batch_axes(), "model", None)
    return x, new_cache, aux, scores


def forward(params: dict, dsg: Optional[dict], cfg: ModelConfig,
            tokens: jax.Array, *, prefix_embeds: Optional[jax.Array] = None,
            cache: Optional[dict] = None, pos0=0,
            live_pages: Optional[int] = None,
            mesh: Optional[Mesh] = None, batch_axes=None,
            last_only: bool = False, ffn_csr: Optional[dict] = None,
            collect_drs_scores: bool = False):
    """tokens (B, S) -> (logits, new_cache, aux_loss)
    [+ drs_scores (L, B, S, G) when collect_drs_scores].

    ffn_csr: serving DSG selection stacks {'idx': (L, B, K),
    'counts': (L, B)} — per-layer group-CSR patterns scanned alongside
    the layer params; the FFN contracts only the listed groups.
    collect_drs_scores (python-static): additionally return each layer's
    DRS group scores of the FFN input — the serving runtime's refresh
    reads them to rewrite patterns off the measured decode window.

    prefix_embeds (B, P, d): VLM stub patch embeddings, prepended.
    cache: stacked per-layer KV {'k': (L,B,Smax,Kv,D), 'v': ...} for decode,
    or a paged-backend view {'pages_k': (L,P,ps,Kv,D), 'pages_v': ...,
    'page_table': (B, max_pages)} (see serving/kv_cache.py; the page table
    is shared by all layers, so it rides outside the layer scan).
    pos0: scalar start position, or a per-lane (B,) vector for continuous
    batching (each batch lane decodes at its own depth).
    live_pages: static page-walk bound for paged decode — the number of
    leading logical pages that cover every lane's depth (the serving
    scheduler computes it per step, bucketed so the decode jit compiles
    a handful of variants); None/0 walks the full table width.
    """
    page_table = None
    if cache is not None and "page_table" in cache:
        page_table = cache["page_table"]
        cache = {"k": cache["pages_k"], "v": cache["pages_v"]}
    x = params["embed"].astype(_dtype(cfg))[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    pos0 = jnp.asarray(pos0)
    if pos0.ndim == 1:
        q_pos = pos0[:, None] + jnp.arange(s)      # (B, S) per-lane
    else:
        q_pos = pos0 + jnp.arange(s)               # (S,)

    r = dsg["r"] if dsg is not None else None
    dsg_stack = _layer_dsg(dsg, cfg)

    def body(xc, scanned):
        p_l, dsg_l, cache_l, csr_l = scanned
        y, new_cache, aux, scores = _block(
            p_l, dsg_l, r, xc, cfg, q_pos, cache_l, pos0, page_table,
            live_pages, mesh, batch_axes, csr_l, collect_drs_scores)
        ys = ((new_cache, aux, scores) if collect_drs_scores
              else (new_cache, aux))
        return y, ys

    if cfg.remat and cache is None:
        body = jax.checkpoint(body)

    x, ys = jax.lax.scan(
        body, x, (params["layers"], dsg_stack, cache, ffn_csr))
    if collect_drs_scores:
        new_cache, aux, drs_scores = ys
    else:
        (new_cache, aux), drs_scores = ys, None
    if page_table is not None:
        new_cache = {"pages_k": new_cache["k"], "pages_v": new_cache["v"],
                     "page_table": page_table}
    x = norm_apply(cfg.norm, params["ln_final"], x)
    if last_only:
        x = x[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(_dtype(cfg))
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if collect_drs_scores:
        return logits, new_cache, jnp.sum(aux), drs_scores
    return logits, new_cache, jnp.sum(aux)


# ---------------------------------------------------------------------------
# task-level steps
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if mask is not None:
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(ce)


def train_loss(params: dict, dsg: Optional[dict], cfg: ModelConfig,
               batch: dict, mesh=None, batch_axes=None) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    prefix = batch.get("prefix_embeds")
    logits, _, aux = forward(params, dsg, cfg, tokens, prefix_embeds=prefix,
                             mesh=mesh, batch_axes=batch_axes)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    return cross_entropy(logits, labels) + 0.01 * aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.float32) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.float32) -> dict:
    """Physical page pool for the paged KV-cache backend
    (serving/kv_cache.py): K/V each (L, n_pages, page_size, Kv, D)."""
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, dsg, cfg: ModelConfig, tokens, cache,
            prefix_embeds=None, mesh=None, batch_axes=None,
            collect_drs_scores: bool = False):
    """Prefill the cache with the prompt; returns (last_logits, cache)
    [+ last-token DRS scores (L, B, G) when collect_drs_scores — what the
    serving runtime seeds a lane's CSR pattern from at admission]."""
    out = forward(params, dsg, cfg, tokens, prefix_embeds=prefix_embeds,
                  cache=cache, pos0=0, mesh=mesh, batch_axes=batch_axes,
                  last_only=True, collect_drs_scores=collect_drs_scores)
    if collect_drs_scores:
        logits, new_kv, _, scores = out
        return logits[:, -1], new_kv, scores[:, :, -1]
    logits, new_kv, _ = out
    return logits[:, -1], new_kv


def decode_step(params, dsg, cfg: ModelConfig, token, cache, pos,
                live_pages=None, mesh=None, batch_axes=None,
                ffn_csr=None, collect_drs_scores: bool = False):
    """One decode step.  token (B, 1), pos scalar or per-lane (B,) vector
    -> (logits (B, V), cache) [+ DRS scores (L, B, G) when
    collect_drs_scores].  live_pages: static paged-walk bound; ffn_csr:
    per-layer group-CSR selection stacks (see forward)."""
    out = forward(params, dsg, cfg, token, cache=cache, pos0=pos,
                  live_pages=live_pages, mesh=mesh, batch_axes=batch_axes,
                  ffn_csr=ffn_csr, collect_drs_scores=collect_drs_scores)
    if collect_drs_scores:
        logits, new_cache, _, scores = out
        return logits[:, -1], new_cache, scores[:, :, 0]
    logits, new_cache, _ = out
    return logits[:, -1], new_cache
