"""Shared layer primitives: norms, embeddings, RoPE, init helpers."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_apply(kind: str, p: dict, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# --- RoPE --------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, head_dim), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]               # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- init helpers ------------------------------------------------------------

def dense_init(key: jax.Array, shape, fan_in: Optional[int] = None,
               dtype=jnp.float32) -> jax.Array:
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / math.sqrt(fan)).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)
