"""Mamba2 (SSD) block — chunked scan form for training/prefill, O(1)-state
recurrent form for decode.  Used by zamba2-7b (hybrid backbone).

Simplifications vs the reference CUDA implementation (DESIGN.md §10):
n_groups=1 (B/C shared across heads), depthwise causal conv (k=4) applied to
the x/B/C stream, scalar-per-head A.  The chunked algorithm follows the SSD
paper: intra-chunk quadratic term + inter-chunk state passed by lax.scan —
sub-quadratic in sequence length and scan-compact in HLO.

DSG site (DESIGN.md §3): the in_projection output is SiLU-gated (z branch),
so DRS estimates the z pre-activations and masks neuron groups of the
(z, x) stream — masked groups skip their out_proj rows in the kernel path.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

CONV_K = 4


class Mamba2Dims(NamedTuple):
    d: int          # model dim
    d_in: int       # inner dim (expand * d)
    heads: int      # H
    head_dim: int   # P = d_in / H
    n: int          # state size N
    chunk: int


def dims(d_model: int, expand: int, n_state: int, heads: int,
         chunk: int) -> Mamba2Dims:
    d_in = expand * d_model
    h = heads or max(1, d_in // 64)
    return Mamba2Dims(d_model, d_in, h, d_in // h, n_state, chunk)


def init_mamba2(key: jax.Array, dm: Mamba2Dims, dtype=jnp.float32) -> dict:
    """Head-parallel TP layout (EXPERIMENTS.md §Perf C3): the in-projection
    is SPLIT per stream instead of one fused (d, 2*d_in+2N+H) matrix —
    w_z/w_x are column-sharded over 'model' so the gate, conv, and the
    whole chunked SSM core run head-sharded (d_in/shards per device);
    the fused row-parallel layout left the entire SSM core replicated
    across the model axis.  B/C/dt are small and stay replicated."""
    ks = jax.random.split(key, 6)
    return {
        "w_z": dense_init(ks[0], (dm.d, dm.d_in), fan_in=dm.d, dtype=dtype),
        "w_x": dense_init(ks[1], (dm.d, dm.d_in), fan_in=dm.d, dtype=dtype),
        "w_bcdt": dense_init(ks[2], (dm.d, 2 * dm.n + dm.heads),
                             fan_in=dm.d, dtype=dtype),
        "conv_x": (jax.random.normal(ks[3], (CONV_K, dm.d_in)) /
                   math.sqrt(CONV_K)).astype(dtype),
        "conv_bc": (jax.random.normal(ks[4], (CONV_K, 2 * dm.n)) /
                    math.sqrt(CONV_K)).astype(dtype),
        "a_log": jnp.zeros((dm.heads,), jnp.float32),
        "dt_bias": jnp.full((dm.heads,), -2.0, jnp.float32),
        "d_skip": jnp.ones((dm.heads,), jnp.float32),
        "w_out": dense_init(ks[5], (dm.d_in, dm.d), fan_in=dm.d_in,
                            dtype=dtype),
    }


def _causal_conv(seq: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along time.  seq (B,S,C), w (K,C).
    Returns (out (B,S,C), new_state (B,K-1,C))."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((seq.shape[0], k - 1, seq.shape[-1]), seq.dtype)
    padded = jnp.concatenate([state, seq], axis=1)
    out = sum(padded[:, i:i + seq.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out), padded[:, -(k - 1):]


def ssd_chunked(xh: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                cmat: jax.Array, dm: Mamba2Dims,
                h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    xh (B,S,H,P), dt (B,S,H) [post-softplus], a (B,S,H) = A*dt (negative),
    bmat/cmat (B,S,N).  Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    b, s, h, p = xh.shape
    q = min(dm.chunk, s)
    if s % q:
        # ragged tail: pad with dt=0 tokens (a = A*dt = 0 -> decay 1,
        # x*dt = 0 -> identity on the carried state); outputs sliced off.
        pad = q - s % q
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        y, hf = ssd_chunked(zf(xh), zf(dt), zf(a), zf(bmat), zf(cmat), dm,
                            h0)
        return y[:, :s], hf
    nc = s // q

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((b, nc, q) + t.shape[2:]), 1, 0)

    xc, dtc, ac = to_chunks(xh), to_chunks(dt), to_chunks(a)
    bc, cc = to_chunks(bmat), to_chunks(cmat)
    if h0 is None:
        h0 = jnp.zeros((b, h, dm.n, p), jnp.float32)

    causal = jnp.tril(jnp.ones((q, q), bool))

    def body(hprev, ch):
        x_i, dt_i, a_i, b_i, c_i = ch
        la = jnp.cumsum(a_i, axis=1)                       # (B,Q,H)
        # intra-chunk quadratic term.  Gate math (cumsum/exp) stays f32;
        # the (B,Q,Q,H) tensors — the dominant HBM traffic of the chunked
        # scan (EXPERIMENTS.md §Perf C) — are cast to the compute dtype
        # before the einsums, with f32 kept for the carried state.
        cb = jnp.einsum("bin,bjn->bij", c_i, b_i)          # (B,Q,Q)
        decay = jnp.exp(la[:, :, None] - la[:, None])      # (B,Q,Q,H) i>=j
        m = (cb[..., None].astype(jnp.float32) * decay
             * causal[None, :, :, None]).astype(xh.dtype)
        xdt = (x_i.astype(jnp.float32) * dt_i[..., None]).astype(xh.dtype)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xdt)
        # inter-chunk from carried state
        y_inter = jnp.einsum("bin,bhnp->bihp", c_i.astype(jnp.float32),
                             hprev) * jnp.exp(la)[..., None]
        # chunk contribution to the state
        w = jnp.exp(la[:, -1:] - la) * dt_i                # (B,Q,H)
        s_c = jnp.einsum("bjn,bjhp->bhnp", b_i.astype(jnp.float32),
                         x_i.astype(jnp.float32) * w[..., None])
        hnew = hprev * jnp.exp(la[:, -1])[:, :, None, None] + s_c
        return hnew, (y_intra.astype(jnp.float32) + y_inter).astype(xh.dtype)

    h_final, yc = jax.lax.scan(body, h0, (xc, dtc, ac, bc, cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, s, h, p)
    return y, h_final


def mamba2_forward(p: dict, x: jax.Array, dm: Mamba2Dims,
                   state: Optional[dict] = None,
                   gate_mask: Optional[jax.Array] = None):
    """Full block.  Training/prefill: state=None.  Returns (y, new_state)
    where state = {'ssm': (B,H,N,P), 'conv': (B,K-1,C)}.

    gate_mask, if given, is an expanded {0,1} neuron mask (B,S,d_in) from
    the DRS over the z branch, applied to the SiLU gate — the DSG
    integration point (masked groups skip z columns / out_proj rows in the
    kernel path)."""
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])          # col-sharded
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"])         # col-sharded
    bcdt = jnp.einsum("bsd,de->bse", x, p["w_bcdt"])    # small, replicated
    bc, dt = bcdt[..., :2 * dm.n], bcdt[..., 2 * dm.n:]
    xs, new_conv_x = _causal_conv(xs, p["conv_x"],
                                  state["conv_x"] if state else None)
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc"],
                                   state["conv_bc"] if state else None)
    bmat, cmat = jnp.split(bc, 2, axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    a = -jnp.exp(p["a_log"]) * dt                                  # (B,S,H)
    xh = xs.reshape(xs.shape[:2] + (dm.heads, dm.head_dim))

    h0 = state["ssm"] if state else None
    if x.shape[1] == 1 and state is not None:
        # decode: single-step recurrence
        hprev = h0
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]     # (B,H,P)
        s_c = jnp.einsum("bn,bhp->bhnp", bmat[:, 0].astype(jnp.float32), xdt)
        hnew = hprev * jnp.exp(a[:, 0])[:, :, None, None] + s_c
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32),
                       hnew)[:, None]
        y = jnp.moveaxis(y, 1, 1)                                  # (B,1,H,P)
        h_final = hnew
    else:
        y, h_final = ssd_chunked(xh, dt, a, bmat, cmat, dm, h0)

    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(x.shape[0], x.shape[1], dm.d_in).astype(x.dtype)
    gate = jax.nn.silu(z)
    if gate_mask is not None:
        gate = gate * gate_mask
    y = y * gate
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])      # row-parallel psum
    return out, {"ssm": h_final, "conv_x": new_conv_x,
                 "conv_bc": new_conv_bc}
