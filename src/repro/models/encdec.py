"""Encoder-decoder transformer (whisper-large-v3 backbone).

The audio frontend (mel filterbank + strided conv stem) is a STUB per the
assignment: input_specs() provides precomputed frame embeddings (B, S, d)
directly to the encoder.  Shapes semantics (DESIGN.md §4): for a shape with
seq_len S, the encoder consumes S frames and the decoder S // dec_ratio
tokens; decode steps attend over the full encoder memory via cross-attention
with precomputed memory K/V.

DSG site: the GELU FFNs of both stacks (paper-faithful: a magnitude-
selective nonlinearity following a wide linear layer).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dsg_linear as dl
from repro.core import projection
from repro.models import attention as attn
from repro.models.layers import embed_init, norm_apply, norm_init


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_enc_layer(key, cfg: ModelConfig) -> dict:
    ka, kf = jax.random.split(key)
    dt = _dtype(cfg)
    return {
        "ln_attn": norm_init(cfg.norm, cfg.d_model, dt),
        "attn": attn.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                    cfg.head_dim, dt),
        "ln_ffn": norm_init(cfg.norm, cfg.d_model, dt),
        "ffn": dl.init_gelu_ffn(kf, cfg.d_model, cfg.d_ff, dt),
    }


def init_dec_layer(key, cfg: ModelConfig) -> dict:
    ka, kx, kf = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = init_enc_layer(jax.random.fold_in(key, 0), cfg)
    p["ln_cross"] = norm_init(cfg.norm, cfg.d_model, dt)
    p["cross"] = attn.init_attention(kx, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                     cfg.head_dim, dt)
    return p


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kd, kt, kh = jax.random.split(key, 4)
    dt = _dtype(cfg)
    n_enc = cfg.enc_layers or cfg.n_layers
    enc_keys = jax.random.split(ke, n_enc)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "tok_embed": embed_init(kt, cfg.vocab, cfg.d_model, dt),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "ln_enc": norm_init(cfg.norm, cfg.d_model, dt),
        "ln_dec": norm_init(cfg.norm, cfg.d_model, dt),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                    / math.sqrt(cfg.d_model)).astype(dt),
    }


def init_dsg(key, params, cfg: ModelConfig) -> Optional[dict]:
    if not cfg.dsg.enabled:
        return None
    k = dl.proj_dim(cfg.d_model, cfg.d_ff, cfg.dsg)
    r = projection.make_projection(key, k, cfg.d_model, dtype=_dtype(cfg))
    return {
        "r": r,
        "fw_enc": jnp.einsum("kd,ldf->lkf", r,
                             params["enc_layers"]["ffn"]["w_up"]),
        "fw_dec": jnp.einsum("kd,ldf->lkf", r,
                             params["dec_layers"]["ffn"]["w_up"]),
    }


def refresh_dsg(dsg, params, cfg):
    if dsg is None:
        return None
    return {
        "r": dsg["r"],
        "fw_enc": jnp.einsum("kd,ldf->lkf", dsg["r"],
                             params["enc_layers"]["ffn"]["w_up"]),
        "fw_dec": jnp.einsum("kd,ldf->lkf", dsg["r"],
                             params["dec_layers"]["ffn"]["w_up"]),
    }


def _ffn(p, dsg_l, r, x, cfg):
    st = {"r": r, "fw": dsg_l} if dsg_l is not None else None
    return dl.gelu_ffn(p, x, st, cfg.dsg)


def encode(params, dsg, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, S, d) stub embeddings -> encoder states (B, S, d)."""
    r = dsg["r"] if dsg else None
    fw = dsg["fw_enc"] if dsg else None
    pos = jnp.arange(frames.shape[1])

    def body(x, scanned):
        p_l, fw_l = scanned
        h = norm_apply(cfg.norm, p_l["ln_attn"], x)
        a, _ = attn.self_attention(p_l["attn"], h, n_heads=cfg.n_heads,
                                   n_kv=cfg.n_kv, rope_theta=cfg.rope_theta,
                                   q_pos=pos, causal=False, window=cfg.window,
                                   shard=cfg.attn_shard)
        x = x + a
        h = norm_apply(cfg.norm, p_l["ln_ffn"], x)
        return x + _ffn(p_l["ffn"], fw_l, r, h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, frames.astype(_dtype(cfg)),
                        (params["enc_layers"], fw))
    return norm_apply(cfg.norm, params["ln_enc"], x)


def decode(params, dsg, cfg: ModelConfig, tokens: jax.Array,
           memory_kv: dict, *, cache=None, pos0=0, last_only=False):
    """Decoder pass.  memory_kv: {'k','v'} (L, B, T, Kv, D) precomputed
    encoder K/V per decoder layer.  cache: self-attn KV for decode."""
    r = dsg["r"] if dsg else None
    fw = dsg["fw_dec"] if dsg else None
    x = params["tok_embed"].astype(_dtype(cfg))[tokens]
    s = x.shape[1]
    q_pos = pos0 + jnp.arange(s)

    def body(xc, scanned):
        p_l, fw_l, mem_l, cache_l = scanned
        h = norm_apply(cfg.norm, p_l["ln_attn"], xc)
        a, new_cache = attn.self_attention(
            p_l["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            rope_theta=cfg.rope_theta, q_pos=q_pos, causal=True,
            window=0, cache=cache_l, cache_pos=pos0, shard=cfg.attn_shard)
        xc = xc + a
        h = norm_apply(cfg.norm, p_l["ln_cross"], xc)
        c = attn.cross_attention(p_l["cross"], h, mem_l["k"], mem_l["v"],
                                 n_heads=cfg.n_heads, q_pos=q_pos)
        xc = xc + c
        h = norm_apply(cfg.norm, p_l["ln_ffn"], xc)
        return xc + _ffn(p_l["ffn"], fw_l, r, h, cfg), new_cache

    if cfg.remat and cache is None:
        body = jax.checkpoint(body)
    x, new_cache = jax.lax.scan(
        body, x, (params["dec_layers"], fw, memory_kv, cache))
    x = norm_apply(cfg.norm, params["ln_dec"], x)
    if last_only:
        x = x[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(_dtype(cfg)))
    return logits, new_cache


def build_memory_kv(params, enc_states: jax.Array) -> dict:
    """Per-decoder-layer cross K/V from encoder states (prefill-time)."""
    def per_layer(p_cross):
        k, v = attn.memory_kv(p_cross, enc_states)
        return {"k": k, "v": v}
    return jax.vmap(per_layer)(params["dec_layers"]["cross"])


def train_loss(params, dsg, cfg: ModelConfig, batch, mesh=None,
               batch_axes=None) -> jax.Array:
    from repro.models.transformer import cross_entropy
    enc = encode(params, dsg, cfg, batch["frames"])
    mem = build_memory_kv(params, enc)
    logits, _ = decode(params, dsg, cfg, batch["tokens"], mem)
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_dec: int, dtype=jnp.float32):
    shape = (cfg.n_layers, batch, max_dec, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, dsg, cfg: ModelConfig, frames, tokens, cache):
    """Encoder pass + decoder prompt prefill.  Returns (last_logits,
    {'self': cache, 'memory': mem})."""
    enc = encode(params, dsg, cfg, frames)
    mem = build_memory_kv(params, enc)
    logits, new_cache = decode(params, dsg, cfg, tokens, mem, cache=cache,
                               pos0=0, last_only=True)
    return logits[:, -1], {"self": new_cache, "memory": mem}


def decode_step(params, dsg, cfg: ModelConfig, token, state, pos):
    logits, new_cache = decode(params, dsg, cfg, token, state["memory"],
                               cache=state["self"], pos0=pos)
    return logits[:, -1], {"self": new_cache, "memory": state["memory"]}
