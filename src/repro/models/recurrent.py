"""Recurrent-family assemblies: xlstm-350m and zamba2-7b.

xLSTM: groups of (slstm_every - 1) mLSTM blocks + 1 sLSTM block, each
wrapped in an up(d->2d)/SiLU-gate/down(d->d) projection pair — the gate
half is the DSG site (DRS estimates the gate pre-activations and masks
neuron groups; masked groups skip gate columns and down-proj rows).

Zamba2: groups of `shared_attn_every` Mamba2 blocks followed by ONE shared
attention+FFN block (weight-shared across all groups, its own KV cache per
invocation).  DSG sites: the Mamba2 z-gate branch (DRS over z columns of
the fused in_proj) and the shared block's SwiGLU FFN.

Both are sub-quadratic in sequence length (chunked scans; the zamba shared
attention uses a sliding window for the long_500k shape) — these two archs
run the long_500k cell (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import drs, masks, projection
from repro.core import dsg_linear as dl
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import xlstm as xl
from repro.models.layers import dense_init, embed_init, norm_apply, norm_init


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _gate_mask(x: jax.Array, r: jax.Array, fw: jax.Array,
               cfg: ModelConfig) -> jax.Array:
    """DRS over a gate branch: x (B,S,d) -> expanded neuron mask (B,S,F)."""
    fx = projection.project_rows(r, x)
    mask, _ = drs.drs_mask(fx, fw, cfg.dsg.drs_cfg())
    return drs.expand_mask(masks.freeze(mask), cfg.dsg.block).astype(x.dtype)


# ===========================================================================
# xLSTM
# ===========================================================================

def _xlstm_groups(cfg: ModelConfig):
    every = cfg.slstm_every or cfg.n_layers
    n_m = every - 1 if cfg.slstm_every else cfg.n_layers
    groups = max(1, cfg.n_layers // max(every, 1))
    return groups, n_m, bool(cfg.slstm_every)


def _init_wrap(key, d, dtype):
    ku, kd = jax.random.split(key)
    return {"ln": norm_init("rmsnorm", d, dtype),
            "w_up": dense_init(ku, (d, 2 * d), fan_in=d, dtype=dtype),
            "w_down": dense_init(kd, (d, d), fan_in=d, dtype=dtype)}


def init_xlstm_model(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    groups, n_m, has_s = _xlstm_groups(cfg)
    dm = xl.mlstm_dims(cfg.d_model, cfg.n_heads)
    ke, km, ks, kh = jax.random.split(key, 4)

    def init_m(k):
        k1, k2 = jax.random.split(k)
        return {"wrap": _init_wrap(k1, cfg.d_model, dt),
                "core": xl.init_mlstm(k2, dm, dt)}

    def init_s(k):
        k1, k2 = jax.random.split(k)
        return {"wrap": _init_wrap(k1, cfg.d_model, dt),
                "core": xl.init_slstm(k2, cfg.d_model, dt)}

    m_keys = jax.random.split(km, groups * n_m).reshape(groups, n_m, 2)
    p = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "mlstm": jax.vmap(jax.vmap(init_m))(m_keys),
        "ln_final": norm_init("rmsnorm", cfg.d_model, dt),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                    / math.sqrt(cfg.d_model)).astype(dt),
    }
    if has_s:
        s_keys = jax.random.split(ks, groups)
        p["slstm"] = jax.vmap(init_s)(s_keys)
    return p


def init_xlstm_dsg(key, params, cfg: ModelConfig) -> Optional[dict]:
    if not cfg.dsg.enabled:
        return None
    d = cfg.d_model
    k = dl.proj_dim(d, d, cfg.dsg)
    r = projection.make_projection(key, k, d, dtype=_dtype(cfg))

    def fw_of(wrap):  # gate half of w_up: (d, d)
        return jnp.einsum("kd,de->ke", r, wrap["w_up"][:, d:])

    st = {"r": r, "fw_m": jax.vmap(jax.vmap(fw_of))(params["mlstm"]["wrap"])}
    if "slstm" in params:
        st["fw_s"] = jax.vmap(fw_of)(params["slstm"]["wrap"])
    return st


def refresh_xlstm_dsg(dsg, params, cfg):
    if dsg is None:
        return None
    d = cfg.d_model
    r = dsg["r"]

    def fw_of(wrap):
        return jnp.einsum("kd,de->ke", r, wrap["w_up"][:, d:])

    out = {"r": r, "fw_m": jax.vmap(jax.vmap(fw_of))(params["mlstm"]["wrap"])}
    if "fw_s" in dsg:
        out["fw_s"] = jax.vmap(fw_of)(params["slstm"]["wrap"])
    return out


def _wrapped_block(wrap, core_apply, x, r, fw, cfg):
    """pre-norm -> up -> (core(a) * silu-gate(g)) -> down -> residual."""
    d = cfg.d_model
    h = norm_apply("rmsnorm", wrap["ln"], x)
    u = jnp.einsum("bsd,de->bse", h, wrap["w_up"])
    a, g = jnp.split(u, 2, axis=-1)
    y, new_state = core_apply(a)
    gate = jax.nn.silu(g)
    if fw is not None:
        gate = gate * _gate_mask(h, r, fw, cfg)
    out = jnp.einsum("bsd,de->bse", y * gate, wrap["w_down"])
    return x + out, new_state


def xlstm_forward(params, dsg, cfg: ModelConfig, tokens,
                  state: Optional[dict] = None, last_only=False):
    """tokens (B,S) -> (logits, new_state).  state carries mLSTM (c, n) and
    sLSTM scalar states for decode."""
    dt = _dtype(cfg)
    groups, n_m, has_s = _xlstm_groups(cfg)
    dm = xl.mlstm_dims(cfg.d_model, cfg.n_heads)
    x = params["embed"].astype(dt)[tokens]
    b = x.shape[0]
    r = dsg["r"] if dsg else None

    if state is None:
        zm = jnp.zeros((groups, n_m, b, dm.heads, dm.dk, dm.dv), jnp.float32)
        zn = jnp.ones((groups, n_m, b, dm.heads, dm.dk), jnp.float32)
        state = {"m_c": zm, "m_n": zn}
        if has_s:
            zs = jnp.zeros((groups, b, cfg.d_model), jnp.float32)
            state["s"] = {"c": zs, "n": zs + 1.0, "m": zs, "h": zs}

    def group_body(xc, scanned):
        p_m, fw_m, mc, mn, p_s, fw_s, s_state = scanned

        def m_body(xc2, sc):
            p_l, fw_l, c0, n0 = sc
            def core(a):
                return xl.mlstm_forward(p_l["core"], a, dm,
                                        {"c": c0, "n": n0})
            y, st = _wrapped_block(p_l["wrap"], core, xc2, r, fw_l, cfg)
            return y, (st["c"], st["n"])

        xc, (mc_new, mn_new) = jax.lax.scan(m_body, xc, (p_m, fw_m, mc, mn))
        new_s = s_state
        if has_s:
            def score(a):
                return xl.slstm_forward(p_s["core"], a, s_state)
            xc, new_s = _wrapped_block(p_s["wrap"], score, xc, r, fw_s, cfg)
        return xc, (mc_new, mn_new, new_s)

    if cfg.remat:
        group_body = jax.checkpoint(group_body)

    fw_m = dsg["fw_m"] if dsg else None
    fw_s = dsg.get("fw_s") if dsg else None
    p_s = params.get("slstm")
    s_state = state.get("s") if has_s else None
    x, (mc, mn, new_s) = jax.lax.scan(
        group_body, x,
        (params["mlstm"], fw_m, state["m_c"], state["m_n"], p_s, fw_s,
         s_state))
    new_state = {"m_c": mc, "m_n": mn}
    if has_s:
        new_state["s"] = new_s
    x = norm_apply("rmsnorm", params["ln_final"], x)
    if last_only:
        x = x[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    return logits, new_state


# ===========================================================================
# Zamba2
# ===========================================================================

def init_zamba_model(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    dm = m2.dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_state, cfg.ssm_heads,
                 cfg.ssm_chunk)
    every = cfg.shared_attn_every
    groups = cfg.n_layers // every
    ke, km, ks, kh = jax.random.split(key, 4)

    def init_mblock(k):
        return {"ln": norm_init(cfg.norm, cfg.d_model, dt),
                "mamba": m2.init_mamba2(k, dm, dt)}

    m_keys = jax.random.split(km, groups * every).reshape(groups, every, 2)
    ka, kf = jax.random.split(ks)
    shared = {
        "ln_attn": norm_init(cfg.norm, cfg.d_model, dt),
        "attn": attn.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                    cfg.head_dim, dt),
        "ln_ffn": norm_init(cfg.norm, cfg.d_model, dt),
        "ffn": dl.init_swiglu(kf, cfg.d_model, cfg.d_ff, dt),
    }
    return {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "mamba": jax.vmap(jax.vmap(init_mblock))(m_keys),
        "shared": shared,                      # ONE set of weights
        "ln_final": norm_init(cfg.norm, cfg.d_model, dt),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                    / math.sqrt(cfg.d_model)).astype(dt),
    }


def init_zamba_dsg(key, params, cfg: ModelConfig) -> Optional[dict]:
    if not cfg.dsg.enabled:
        return None
    dt = _dtype(cfg)
    dm = m2.dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_state, cfg.ssm_heads,
                 cfg.ssm_chunk)
    k = dl.proj_dim(cfg.d_model, dm.d_in, cfg.dsg)
    r = projection.make_projection(key, k, cfg.d_model, dtype=dt)

    def fw_z(mb):  # z projection: (d, d_in)
        return jnp.einsum("kd,de->ke", r, mb["w_z"])

    return {
        "r": r,
        "fw_z": jax.vmap(jax.vmap(fw_z))(params["mamba"]["mamba"]),
        "fw_shared": jnp.einsum("kd,df->kf", r,
                                params["shared"]["ffn"]["w_gate"]),
    }


def refresh_zamba_dsg(dsg, params, cfg):
    if dsg is None:
        return None
    dm = m2.dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_state, cfg.ssm_heads,
                 cfg.ssm_chunk)
    r = dsg["r"]

    def fw_z(mb):
        return jnp.einsum("kd,de->ke", r, mb["w_z"])

    return {"r": r,
            "fw_z": jax.vmap(jax.vmap(fw_z))(params["mamba"]["mamba"]),
            "fw_shared": jnp.einsum("kd,df->kf", r,
                                    params["shared"]["ffn"]["w_gate"])}


def zamba_forward(params, dsg, cfg: ModelConfig, tokens,
                  state: Optional[dict] = None, pos0=0, last_only=False):
    """state: {'ssm': (G,M,B,H,N,P), 'conv': (G,M,B,K-1,C),
               'k'/'v': (G,B,Smax,Kv,D)} for decode; None for training."""
    dt = _dtype(cfg)
    dm = m2.dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_state, cfg.ssm_heads,
                 cfg.ssm_chunk)
    every = cfg.shared_attn_every
    groups = cfg.n_layers // every
    x = params["embed"].astype(dt)[tokens]
    b, s = x.shape[:2]
    q_pos = pos0 + jnp.arange(s)
    r = dsg["r"] if dsg else None
    fw_sh = dsg["fw_shared"] if dsg else None
    decode = state is not None

    def group_body(xc, scanned):
        p_g, fw_z_g, ssm_g, cx_g, cbc_g, kv_g = scanned
        if cfg.seq_sharded_residual:
            from repro.parallel import context as pctx
            xc = pctx.constrain(xc, pctx.batch_axes(), "model", None)

        def m_body(xc2, sc):
            p_l, fw_l, ssm_l, cx_l, cbc_l = sc
            h = norm_apply(cfg.norm, p_l["ln"], xc2)
            gmask = None
            if fw_l is not None:
                gmask = _gate_mask(h, r, fw_l, cfg)
            st = ({"ssm": ssm_l, "conv_x": cx_l, "conv_bc": cbc_l}
                  if decode else None)
            y, new_st = m2.mamba2_forward(p_l["mamba"], h, dm, st, gmask)
            return xc2 + y, (new_st["ssm"], new_st["conv_x"],
                             new_st["conv_bc"])

        xc, (ssm_new, cx_new, cbc_new) = jax.lax.scan(
            m_body, xc, (p_g, fw_z_g, ssm_g, cx_g, cbc_g))

        sh = params["shared"]
        h = norm_apply(cfg.norm, sh["ln_attn"], xc)
        cache_pos = pos0
        cache_kv_pos = None
        if decode and cfg.window and kv_g is not None:
            w = kv_g["k"].shape[1]
            cache_pos = pos0 % w       # ring-buffer slot for windowed cache
            cache_kv_pos = pos0 - ((pos0 - jnp.arange(w)) % w)
        a, kv_new = attn.self_attention(
            sh["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            rope_theta=cfg.rope_theta, q_pos=q_pos, causal=True,
            window=cfg.window, cache=kv_g if decode else None,
            cache_pos=cache_pos, cache_kv_pos=cache_kv_pos,
            shard=cfg.attn_shard)
        xc = xc + a
        h = norm_apply(cfg.norm, sh["ln_ffn"], xc)
        st = {"r": r, "fw": fw_sh} if fw_sh is not None else None
        xc = xc + dl.swiglu_ffn(sh["ffn"], h, st, cfg.dsg)
        return xc, (ssm_new, cx_new, cbc_new, kv_new)

    if cfg.remat and not decode:
        group_body = jax.checkpoint(group_body)

    if decode:
        ssm0, cx0, cbc0 = state["ssm"], state["conv_x"], state["conv_bc"]
        kv0 = {"k": state["k"], "v": state["v"]}
    else:
        ssm0 = jnp.zeros((groups, every, b, dm.heads, dm.n, dm.head_dim),
                         jnp.float32)
        cx0 = jnp.zeros((groups, every, b, m2.CONV_K - 1, dm.d_in), dt)
        cbc0 = jnp.zeros((groups, every, b, m2.CONV_K - 1, 2 * dm.n), dt)
        kv0 = None

    fw_z = dsg["fw_z"] if dsg else None
    x, (ssm_f, cx_f, cbc_f, kv_f) = jax.lax.scan(
        group_body, x, (params["mamba"], fw_z, ssm0, cx0, cbc0, kv0))
    x = norm_apply(cfg.norm, params["ln_final"], x)
    if last_only:
        x = x[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    new_state = {"ssm": ssm_f, "conv_x": cx_f, "conv_bc": cbc_f}
    if kv_f is not None:
        new_state.update(kv_f)
    return logits, new_state


def init_zamba_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     dtype=jnp.float32) -> dict:
    dm = m2.dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_state, cfg.ssm_heads,
                 cfg.ssm_chunk)
    every = cfg.shared_attn_every
    groups = cfg.n_layers // every
    kv_len = min(max_seq, cfg.window) if cfg.window else max_seq
    return {
        "ssm": jnp.zeros((groups, every, batch, dm.heads, dm.n, dm.head_dim),
                         jnp.float32),
        "conv_x": jnp.zeros((groups, every, batch, m2.CONV_K - 1, dm.d_in),
                            dtype),
        "conv_bc": jnp.zeros((groups, every, batch, m2.CONV_K - 1,
                              2 * dm.n), dtype),
        "k": jnp.zeros((groups, batch, kv_len, cfg.n_kv, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((groups, batch, kv_len, cfg.n_kv, cfg.head_dim),
                       dtype),
    }
