"""xLSTM blocks (mLSTM chunked-parallel + sLSTM recurrent) for xlstm-350m.

mLSTM: matrix-memory LSTM — per head a (Dk x Dv) covariance state with
exponential input gate and sigmoid forget gate; mathematically a gated
linear attention, so the chunked scan mirrors mamba2.ssd_chunked with
per-head q/k/v and a key-dim normalizer state.

sLSTM: scalar-memory LSTM with exponential gating and stabilizer state,
sequential lax.scan over time (recurrent by construction — this is the
paper's point: xLSTM mixes both).  Diagonal recurrent weights (a documented
simplification of the block-diagonal ones, DESIGN.md §10).

DSG site: the block up-projection (d -> 2d, SiLU-gated) — DRS masks neuron
groups of the gated stream, mirroring the FFN treatment.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class MLSTMDims(NamedTuple):
    d: int
    heads: int
    dk: int     # key/query dim per head
    dv: int     # value dim per head
    chunk: int


def mlstm_dims(d: int, heads: int, chunk: int = 128) -> MLSTMDims:
    return MLSTMDims(d, heads, d // heads, d // heads, chunk)


def init_mlstm(key: jax.Array, dm: MLSTMDims, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    h, dk, dv = dm.heads, dm.dk, dm.dv
    return {
        "w_qkv": dense_init(ks[0], (dm.d, h * (2 * dk + dv)), fan_in=dm.d,
                            dtype=dtype),
        "w_gates": dense_init(ks[1], (dm.d, 2 * h), fan_in=dm.d,
                              dtype=jnp.float32),
        "b_gates": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "w_out": dense_init(ks[2], (h * dv, dm.d), fan_in=h * dv, dtype=dtype),
        "skip": jnp.ones((h,), jnp.float32),
    }


def mlstm_chunked(q, k, v, log_f, i_gate, dm: MLSTMDims,
                  c0=None, n0=None):
    """Chunked gated-linear-attention scan.

    q/k/v (B,S,H,D*), log_f (B,S,H) = log sigmoid(f~), i_gate (B,S,H) >= 0.
    State C (B,H,Dk,Dv), normalizer n (B,H,Dk).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    qn = q / math.sqrt(dk)
    qchunk = min(dm.chunk, s)
    nc = s // qchunk
    assert nc * qchunk == s

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((b, nc, qchunk) + t.shape[2:]), 1, 0)

    qc, kc, vc = to_chunks(qn), to_chunks(k), to_chunks(v)
    fc, ic = to_chunks(log_f), to_chunks(i_gate)
    if c0 is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.ones((b, h, dk), jnp.float32)
    causal = jnp.tril(jnp.ones((qchunk, qchunk), bool))

    def body(carry, ch):
        c_prev, n_prev = carry
        q_i, k_i, v_i, f_i, i_i = ch
        lf = jnp.cumsum(f_i, axis=1)                        # (B,Q,H)
        decay = jnp.exp(lf[:, :, None] - lf[:, None])       # (B,Q,Q,H)
        qk = jnp.einsum("bihd,bjhd->bijh", q_i.astype(jnp.float32),
                        k_i.astype(jnp.float32))
        m = qk * decay * causal[None, :, :, None] * i_i[:, None]
        y_intra = jnp.einsum("bijh,bjhv->bihv", m, v_i.astype(jnp.float32))
        n_intra = jnp.sum(m, axis=2)                        # (B,Q,H)
        y_inter = jnp.einsum("bihd,bhdv->bihv", q_i.astype(jnp.float32),
                             c_prev) * jnp.exp(lf)[..., None]
        n_inter = jnp.einsum("bihd,bhd->bih", q_i.astype(jnp.float32),
                             n_prev) * jnp.exp(lf)
        denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)[..., None]
        y = (y_intra + y_inter) / denom
        w = jnp.exp(lf[:, -1:] - lf) * i_i                  # (B,Q,H)
        c_new = c_prev * jnp.exp(lf[:, -1])[:, :, None, None] + jnp.einsum(
            "bjhd,bjhv->bhdv", k_i.astype(jnp.float32) * w[..., None],
            v_i.astype(jnp.float32))
        n_new = n_prev * jnp.exp(lf[:, -1])[:, :, None] + jnp.sum(
            k_i.astype(jnp.float32) * w[..., None], axis=1)
        return (c_new, n_new), y.astype(q.dtype)

    (c_f, n_f), yc = jax.lax.scan(body, (c0, n0), (qc, kc, vc, fc, ic))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, s, h, dv)
    return y, (c_f, n_f)


def mlstm_forward(p: dict, x: jax.Array, dm: MLSTMDims,
                  state: Optional[dict] = None):
    b, s, _ = x.shape
    h, dk, dv = dm.heads, dm.dk, dm.dv
    qkv = jnp.einsum("bsd,de->bse", x, p["w_qkv"])
    q, k, v = jnp.split(qkv.reshape(b, s, h, 2 * dk + dv),
                        [dk, 2 * dk], axis=-1)
    gates = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["w_gates"]) \
        + p["b_gates"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)             # (B,S,H)
    i_gate = jnp.exp(jnp.minimum(i_raw, 8.0))               # stabilized exp gate
    log_f = jax.nn.log_sigmoid(f_raw)

    if s == 1 and state is not None:
        c_prev, n_prev = state["c"], state["n"]
        f1 = jnp.exp(log_f[:, 0])                           # (B,H)
        kv = jnp.einsum("bhd,bhv->bhdv", k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32))
        c_new = c_prev * f1[..., None, None] + i_gate[:, 0][..., None, None] * kv
        n_new = n_prev * f1[..., None] + i_gate[:, 0][..., None] * \
            k[:, 0].astype(jnp.float32)
        qs = q[:, 0].astype(jnp.float32) / math.sqrt(dk)
        num = jnp.einsum("bhd,bhdv->bhv", qs, c_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n_new)), 1.0)
        y = (num / den[..., None])[:, None]                 # (B,1,H,Dv)
        c_f, n_f = c_new, n_new
    else:
        c0 = state["c"] if state else None
        n0 = state["n"] if state else None
        y, (c_f, n_f) = mlstm_chunked(q, k, v, log_f, i_gate, dm, c0, n0)

    out = jnp.einsum("bse,ed->bsd",
                     y.astype(x.dtype).reshape(b, s, h * dv), p["w_out"])
    return out, {"c": c_f, "n": n_f}


# --- sLSTM -------------------------------------------------------------------

def init_slstm(key: jax.Array, d: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), fan_in=d, dtype=dtype),
        "r_diag": (jax.random.normal(ks[1], (4, d)) * 0.1).astype(jnp.float32),
        "bias": jnp.concatenate([jnp.zeros((d,)), 2.0 * jnp.ones((d,)),
                                 jnp.zeros((2 * d,))]),
    }


def slstm_forward(p: dict, x: jax.Array, state: Optional[dict] = None):
    """Sequential sLSTM over time.  x (B,S,d).  State {'c','n','m','h'}."""
    b, s, d = x.shape
    pre = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["w_in"]) + p["bias"]
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = {"c": zeros, "n": zeros + 1.0, "m": zeros, "h": zeros}

    def step(carry, pre_t):
        c, n, m, h = carry["c"], carry["n"], carry["m"], carry["h"]
        rec = p["r_diag"] * h[:, None, :]                  # (B,4,d)
        z_r, f_r, i_r, o_r = (pre_t[:, :d] + rec[:, 0],
                              pre_t[:, d:2 * d] + rec[:, 1],
                              pre_t[:, 2 * d:3 * d] + rec[:, 2],
                              pre_t[:, 3 * d:] + rec[:, 3])
        m_new = jnp.maximum(f_r + m, i_r)                  # stabilizer
        i_g = jnp.exp(i_r - m_new)
        f_g = jnp.exp(f_r + m - m_new)
        z = jnp.tanh(z_r)
        o = jax.nn.sigmoid(o_r)
        c_new = f_g * c + i_g * z
        n_new = f_g * n + i_g
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        new = {"c": c_new, "n": n_new, "m": m_new, "h": h_new}
        return new, h_new

    pre_t = jnp.moveaxis(pre, 1, 0)                        # (S,B,4d)
    final, hs = jax.lax.scan(step, state, pre_t)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)             # (B,S,d)
    return y, final
