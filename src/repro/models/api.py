"""Family-dispatching model API used by the launcher, dry-run, and tests.

Every architecture family exposes the same verbs:
  init_model / init_dsg / refresh_dsg
  train_loss(params, dsg, cfg, batch)            -> scalar
  make_cache(cfg, batch, max_seq)                -> decode state pytree
  prefill(params, dsg, cfg, inputs, cache)       -> (last_logits, state)
  decode_step(params, dsg, cfg, token, state, pos) -> (logits, state)
  make_inputs(cfg, shape, kind, concrete)        -> batch pytree

make_cache builds the dense worst-case layout; serving picks the cache
LAYOUT through repro.serving.kv_cache backends ("dense" | "paged") and
passes the backend's view into prefill/decode_step — decoder-family
decode also accepts the paged view ({'pages_k','pages_v','page_table'},
see serving/kv_cache.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, recurrent, transformer

DECODER_FAMILIES = ("dense", "moe", "vlm")


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    if cfg.family in DECODER_FAMILIES:
        return transformer.init_model(key, cfg)
    if cfg.family == "encdec":
        return encdec.init_model(key, cfg)
    if cfg.family == "xlstm":
        return recurrent.init_xlstm_model(key, cfg)
    if cfg.family == "zamba":
        return recurrent.init_zamba_model(key, cfg)
    raise ValueError(cfg.family)


def init_dsg(key: jax.Array, params: dict, cfg: ModelConfig) -> Optional[dict]:
    if cfg.family in DECODER_FAMILIES:
        return transformer.init_dsg(key, params, cfg)
    if cfg.family == "encdec":
        return encdec.init_dsg(key, params, cfg)
    if cfg.family == "xlstm":
        return recurrent.init_xlstm_dsg(key, params, cfg)
    if cfg.family == "zamba":
        return recurrent.init_zamba_dsg(key, params, cfg)
    raise ValueError(cfg.family)


def refresh_dsg(dsg, params, cfg: ModelConfig):
    if cfg.family in DECODER_FAMILIES:
        return transformer.refresh_dsg(dsg, params, cfg)
    if cfg.family == "encdec":
        return encdec.refresh_dsg(dsg, params, cfg)
    if cfg.family == "xlstm":
        return recurrent.refresh_xlstm_dsg(dsg, params, cfg)
    if cfg.family == "zamba":
        return recurrent.refresh_zamba_dsg(dsg, params, cfg)
    raise ValueError(cfg.family)


def train_loss(params, dsg, cfg: ModelConfig, batch, mesh=None,
               batch_axes=None) -> jax.Array:
    if cfg.family in DECODER_FAMILIES:
        return transformer.train_loss(params, dsg, cfg, batch, mesh,
                                      batch_axes)
    if cfg.family == "encdec":
        return encdec.train_loss(params, dsg, cfg, batch, mesh, batch_axes)
    if cfg.family == "xlstm":
        logits, _ = recurrent.xlstm_forward(params, dsg, cfg,
                                            batch["tokens"])
        return transformer.cross_entropy(logits, batch["labels"])
    if cfg.family == "zamba":
        logits, _ = recurrent.zamba_forward(params, dsg, cfg,
                                            batch["tokens"])
        return transformer.cross_entropy(logits, batch["labels"])
    raise ValueError(cfg.family)


def make_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    dt = dtype or _dtype(cfg)
    if cfg.family in DECODER_FAMILIES:
        return transformer.init_cache(cfg, batch, max_seq, dt)
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_seq // cfg.dec_ratio, dt)
    if cfg.family == "xlstm":
        return None   # state built lazily inside xlstm_forward
    if cfg.family == "zamba":
        return recurrent.init_zamba_cache(cfg, batch, max_seq, dt)
    raise ValueError(cfg.family)


def prefill(params, dsg, cfg: ModelConfig, inputs: dict, cache,
            mesh=None, batch_axes=None, collect_drs_scores: bool = False):
    if cfg.family in DECODER_FAMILIES:
        return transformer.prefill(params, dsg, cfg, inputs["tokens"], cache,
                                   prefix_embeds=inputs.get("prefix_embeds"),
                                   mesh=mesh, batch_axes=batch_axes,
                                   collect_drs_scores=collect_drs_scores)
    if collect_drs_scores:
        raise NotImplementedError(
            f"DRS score collection is a decoder-family serving feature "
            f"(family {cfg.family!r})")
    if cfg.family == "encdec":
        return encdec.prefill(params, dsg, cfg, inputs["frames"],
                              inputs["tokens"], cache)
    if cfg.family == "xlstm":
        logits, st = recurrent.xlstm_forward(params, dsg, cfg,
                                             inputs["tokens"],
                                             last_only=True)
        return logits[:, -1], st
    if cfg.family == "zamba":
        logits, st = recurrent.zamba_forward(params, dsg, cfg,
                                             inputs["tokens"], state=None,
                                             last_only=True)
        return logits[:, -1], st
    raise ValueError(cfg.family)


def decode_step(params, dsg, cfg: ModelConfig, token, state, pos,
                live_pages=None, mesh=None, batch_axes=None,
                ffn_csr=None, collect_drs_scores: bool = False):
    if cfg.family in DECODER_FAMILIES:
        return transformer.decode_step(params, dsg, cfg, token, state, pos,
                                       live_pages=live_pages, mesh=mesh,
                                       batch_axes=batch_axes,
                                       ffn_csr=ffn_csr,
                                       collect_drs_scores=collect_drs_scores)
    if ffn_csr is not None or collect_drs_scores:
        raise NotImplementedError(
            f"group-CSR decode / DRS score collection are decoder-family "
            f"serving features (family {cfg.family!r})")
    if cfg.family == "encdec":
        return encdec.decode_step(params, dsg, cfg, token, state, pos)
    if cfg.family == "xlstm":
        logits, st = recurrent.xlstm_forward(params, dsg, cfg, token,
                                             state=state)
        return logits[:, -1], st
    if cfg.family == "zamba":
        logits, st = recurrent.zamba_forward(params, dsg, cfg, token,
                                             state=state, pos0=pos)
        return logits[:, -1], st
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# per-slot cache surgery — DEPRECATED thin wrappers
# ---------------------------------------------------------------------------
#
# The engine-facing cache surface now lives in repro.serving.kv_cache: a
# pluggable KVCacheBackend ("dense" | "paged") builds and mutates an opaque
# CacheHandle pytree (make / write / ensure / free / view_for_attention),
# and the serving scheduler drives that protocol instead of these helpers.
# They predate the backend API and are kept as thin wrappers for callers
# that still hold raw dense cache dicts; they assume every cache leaf
# carries the batch on axis 1 (L, B, ...), which holds for transformer and
# encdec caches.

def make_slot_cache(cfg: ModelConfig, max_seq: int, dtype=None):
    """Deprecated: a 1-lane dense cache for solo prompt prefill.  Same as
    ``make_cache(cfg, 1, max_seq)``; new code should build caches through a
    serving.kv_cache backend."""
    return make_cache(cfg, 1, max_seq, dtype)


def prefill_slot(params, dsg, cfg: ModelConfig, tokens, lane_cache,
                 mesh=None, batch_axes=None):
    """Deprecated: prefill a single prompt lane.  tokens (1, P) int32 ->
    (last_logits (1, V), filled 1-lane cache).  Same as ``prefill`` with a
    ``{"tokens": ...}`` batch."""
    return prefill(params, dsg, cfg, {"tokens": tokens}, lane_cache,
                   mesh=mesh, batch_axes=batch_axes)


def merge_slot_cache(cache, lane_cache, slot):
    """Deprecated: scatter a 1-lane cache into lane `slot` of a batched
    dense cache.  Delegates to serving.kv_cache.dense_merge (the
    DenseBackend write primitive)."""
    from repro.serving.kv_cache import dense_merge
    return dense_merge(cache, lane_cache, slot)


# ---------------------------------------------------------------------------
# input construction (ShapeDtypeStructs for dry-run, arrays for smoke tests)
# ---------------------------------------------------------------------------

def make_inputs(cfg: ModelConfig, shape: ShapeConfig, *,
                concrete: bool = False, seed: int = 0) -> dict:
    """Batch pytree for the given shape cell.

    kind='train': {'tokens','labels'} (+family extras).
    kind='prefill': prompt inputs.
    kind='decode': single-token inputs (cache built separately).
    """
    b, s = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)

    def tok(shp):
        if concrete:
            rng = np.random.default_rng(seed)
            return jnp.asarray(rng.integers(0, cfg.vocab, shp, dtype=np.int32))
        return jax.ShapeDtypeStruct(shp, jnp.int32)

    def emb(shp):
        if concrete:
            rng = np.random.default_rng(seed + 1)
            return jnp.asarray(rng.standard_normal(shp), dtype=dt)
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.family == "encdec":
        sd = max(1, s // cfg.dec_ratio)
        if shape.kind == "train":
            return {"frames": emb((b, s, cfg.d_model)),
                    "tokens": tok((b, sd)), "labels": tok((b, sd))}
        if shape.kind == "prefill":
            return {"frames": emb((b, s, cfg.d_model)), "tokens": tok((b, sd))}
        return {"token": tok((b, 1))}

    if cfg.family == "vlm":
        p = min(cfg.vision_prefix, max(s // 4, 1))
        st = s - p
        if shape.kind == "train":
            return {"prefix_embeds": emb((b, p, cfg.d_model)),
                    "tokens": tok((b, st)), "labels": tok((b, st))}
        if shape.kind == "prefill":
            return {"prefix_embeds": emb((b, p, cfg.d_model)),
                    "tokens": tok((b, st))}
        return {"token": tok((b, 1))}

    if shape.kind == "train":
        return {"tokens": tok((b, s)), "labels": tok((b, s))}
    if shape.kind == "prefill":
        return {"tokens": tok((b, s))}
    return {"token": tok((b, 1))}
