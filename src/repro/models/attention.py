"""Attention: GQA/MHA + RoPE, KV-cache decode, chunked (flash-style) path.

Sharding modes (DESIGN.md §6):
  * "head" — Megatron-style TP: q/o projections sharded by head over the
    'model' axis (requires n_heads % model_shards == 0); kv projections
    replicated when n_kv < model_shards (small fraction of FLOPs).
  * "seq"  — sequence-parallel self-attention for head counts that do not
    divide the model axis (llama3.2 24H, llama4 40H, llava 56H, whisper
    20H): queries sharded over sequence, KV gathered — works for any head
    count and keeps FLOPs fully partitioned.

The decode KV cache is always sequence-sharded over 'model'
(flash-decode-style split-KV; the softmax reduction over the sharded key
axis becomes a cross-shard LSE combine inserted by SPMD partitioning).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init
from repro.parallel import context as pctx

NEG = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array   # (d, H, hd)
    wk: jax.Array   # (d, Kv, hd)
    wv: jax.Array   # (d, Kv, hd)
    wo: jax.Array   # (H, hd, d)


def init_attention(key: jax.Array, d: int, n_heads: int, n_kv: int,
                   head_dim: int, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, n_heads, head_dim), fan_in=d, dtype=dtype),
        "wk": dense_init(kk, (d, n_kv, head_dim), fan_in=d, dtype=dtype),
        "wv": dense_init(kv, (d, n_kv, head_dim), fan_in=d, dtype=dtype),
        "wo": dense_init(ko, (n_heads, head_dim, d),
                         fan_in=n_heads * head_dim, dtype=dtype),
    }


def _mask(q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
          window: int) -> jax.Array:
    """Boolean validity mask from absolute positions.

    q_pos (S,) -> (S, T); per-lane q_pos (B, S) -> (B, S, T) (continuous
    batching: each lane decodes at its own position)."""
    qp = q_pos[..., :, None]
    kp = kv_pos[None, :]
    shape = jnp.broadcast_shapes(qp.shape, kp.shape)
    m = jnp.broadcast_to(kp >= 0, shape)   # ring-buffer slots not yet written
    if causal:
        m = m & (kp <= qp)
    if window > 0:
        m = m & (kp > qp - window)
    return m


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, Kv, D) -> (B, T, H, D) by repeating each kv head H/Kv times."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def attend_direct(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_pos: jax.Array, kv_pos: jax.Array,
                  causal: bool, window: int,
                  bf16_scores: bool = False) -> jax.Array:
    """Direct softmax attention; q (B,S,H,D), k/v (B,T,H,D).

    bf16_scores (EXPERIMENTS.md §Perf A6): keep the (B,H,S,T) score and
    probability tensors in bf16 (softmax max/sum statistics in f32) —
    halves the dominant attention HBM traffic; standard flash-kernel
    numerics."""
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    m = _mask(q_pos, kv_pos, causal, window)
    # (S,T) masks broadcast over (B,H); per-lane (B,S,T) masks over H only
    m = m[:, None] if m.ndim == 3 else m[None, None]
    if bf16_scores and q.dtype == jnp.bfloat16:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.bfloat16) * scale
        s = jnp.where(m, s, jnp.bfloat16(NEG))
        mx = jnp.max(s.astype(jnp.float32), axis=-1, keepdims=True)
        p = jnp.exp(s.astype(jnp.float32) - mx)
        p = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(jnp.bfloat16)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v)
        return o.astype(q.dtype)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(m, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, kv_pos: jax.Array,
                   causal: bool, window: int,
                   q_chunk: int = 1024, kv_chunk: int = 1024,
                   q_spec=None) -> jax.Array:
    """Flash-style online-softmax attention, double-chunked via lax.scan.

    Keeps the live score tile at (B,H,q_chunk,kv_chunk) — required for the
    32k/500k shapes where the dense (S,T) score matrix cannot exist.
    """
    b, s_len, h, d = q.shape
    t_len = k.shape[1]
    q_chunk = min(q_chunk, s_len)
    kv_chunk = min(kv_chunk, t_len)
    nq, nk = s_len // q_chunk, t_len // kv_chunk
    assert nq * q_chunk == s_len and nk * kv_chunk == t_len, (
        f"chunking must tile exactly: {s_len}/{q_chunk}, {t_len}/{kv_chunk}")

    qc = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nk, kv_chunk, h, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, kv_chunk, h, d), 1, 0)
    qpc = q_pos.reshape(nq, q_chunk)
    kpc = kv_pos.reshape(nk, kv_chunk)
    scale = 1.0 / math.sqrt(d)

    def q_body(_, qi):
        q_i, qpos_i = qi
        if q_spec is not None:
            # per-chunk sharding constraint (seq/head parallel attention)
            q_i = pctx.constrain(q_i, *q_spec)

        def kv_body(carry, ki):
            k_j, v_j, kpos_j = ki
            m_run, l_run, acc = carry
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            msk = _mask(qpos_i, kpos_j, causal, window)[None, None]
            s = jnp.where(msk, s, NEG)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]) * msk
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, acc), None

        init = (jnp.full((b, h, q_chunk), NEG, jnp.float32),
                jnp.zeros((b, h, q_chunk), jnp.float32),
                jnp.zeros((b, h, q_chunk, d), jnp.float32))
        (m_run, l_run, acc), _ = jax.lax.scan(kv_body, init, (kc, vc, kpc))
        out = acc / jnp.maximum(l_run, 1e-20)[..., None]
        return None, jnp.moveaxis(out, 1, 2)          # (b, q_chunk, h, d)

    _, out = jax.lax.scan(q_body, None, (qc, qpc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s_len, h, d)
    return out.astype(q.dtype)


# --- full layers -------------------------------------------------------------

CHUNK_THRESHOLD = 1 << 24   # S*T above which the chunked path is used


def _use_paged_kernel(mode: str) -> bool:
    """Resolve the paged decode executor: "kernel" forces the Pallas
    kernel (interpret mode on CPU), "xla" forces the bounded-gather
    fallback, "auto" picks the kernel only where Mosaic compiles it."""
    if mode == "auto":
        return jax.default_backend() == "tpu"
    if mode not in ("kernel", "xla"):
        raise ValueError(f"unknown paged_attn_kernel mode {mode!r}")
    return mode == "kernel"


def self_attention(p: dict, x: jax.Array, *, n_heads: int, n_kv: int,
                   rope_theta: float, q_pos: jax.Array,
                   causal: bool = True, window: int = 0,
                   cache: Optional[dict] = None,
                   cache_pos: Optional[jax.Array] = None,
                   cache_kv_pos: Optional[jax.Array] = None,
                   page_table: Optional[jax.Array] = None,
                   live_pages: Optional[int] = None,
                   paged_kernel: str = "auto",
                   shard: str = "auto", bf16_scores: bool = False):
    """Self-attention over x (B, S, d).

    Training / prefill: cache=None -> returns (out, new_kv) where new_kv is
    the (B, S, Kv, D) tensors (prefill stores them into the cache).
    Decode: cache={'k','v'} of (B, Smax, Kv, D), cache_pos = write position
    (ring-buffer slot for windowed caches) — a scalar shared by the batch,
    or a per-lane (B,) vector for continuous batching where every slot sits
    at its own depth (q_pos is then (B, S)).  cache_kv_pos = absolute
    positions held by each cache slot (defaults to arange(Smax)) -> returns
    (out, updated_cache).

    Paged decode (serving/kv_cache.py PagedBackend): page_table is the
    per-lane (B, max_pages) int32 map, cache={'k','v'} are the physical
    page pools (P, page_size, Kv, D), and cache_pos carries the per-lane
    depths.  Two executors behind `paged_kernel` (see _use_paged_kernel):

      * Pallas kernel (kernels/paged_attention.py): fused scatter +
        depth-bounded page walk + flash decode — per lane, only pages at
        or below `cache_pos` are read from HBM.
      * XLA fallback: scatter through the page table, then gather the
        leading `live_pages` pages (a static bound the scheduler sizes
        to the deepest live lane, bucketed to limit recompiles) —
        non-Pallas platforms stop paying worst-case whole-window reads.

    In both, logical positions beyond a lane's depth read junk
    (unallocated rows point at the scratch page) but are masked by
    `kp <= qp` exactly as unwritten dense slots are.  Per-lane
    single-token decode only.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    rope_pos = q_pos if q_pos.ndim == 2 else q_pos[None, :]
    q = apply_rope(q, rope_pos, rope_theta) if rope_theta > 0 else q
    k_new = (apply_rope(k_new, rope_pos, rope_theta)
             if rope_theta > 0 else k_new)

    paged = page_table is not None
    if cache is None:
        if paged:
            raise NotImplementedError(
                "paged KV cache has no prefill path: prefill runs on a "
                "dense 1-lane cache and is spliced in by the backend")
        k, v = k_new, v_new
        kv_pos = q_pos
    elif paged:
        if s != 1 or jnp.ndim(cache_pos) != 1:
            raise NotImplementedError(
                "paged KV cache supports per-lane single-token decode only")
        ps_sz = cache["k"].shape[1]
        max_pages = page_table.shape[1]
        walk = min(live_pages, max_pages) if live_pages else max_pages
        if _use_paged_kernel(paged_kernel):
            from repro.kernels import ops as kernel_ops
            o, pk, pv = kernel_ops.paged_decode_attention(
                q[:, 0], k_new[:, 0], v_new[:, 0], cache["k"], cache["v"],
                page_table, cache_pos, window=window, num_pages=walk)
            out = jnp.einsum("bshk,hkd->bsd", o[:, None], p["wo"])
            # pool sharding is deferred to the kernel's page addressing
            return out, {"k": pk, "v": pv}
        lanes = jnp.arange(b)
        pp = page_table[lanes, cache_pos // ps_sz]
        off = cache_pos % ps_sz
        pk = cache["k"].at[pp, off].set(k_new[:, 0].astype(cache["k"].dtype))
        pv = cache["v"].at[pp, off].set(v_new[:, 0].astype(cache["v"].dtype))
        t = jnp.arange(walk * ps_sz)
        k = pk[page_table[:, t // ps_sz], t % ps_sz]
        v = pv[page_table[:, t // ps_sz], t % ps_sz]
        kv_pos = (cache_kv_pos[..., :t.shape[0]]
                  if cache_kv_pos is not None else t)
    elif jnp.ndim(cache_pos) == 1:
        # per-lane scatter: lane i writes its tokens at its own position
        upd = jax.vmap(
            lambda c, n, pp: jax.lax.dynamic_update_slice(c, n, (pp, 0, 0)))
        k = upd(cache["k"], k_new.astype(cache["k"].dtype), cache_pos)
        v = upd(cache["v"], v_new.astype(cache["v"].dtype), cache_pos)
        kv_pos = (cache_kv_pos if cache_kv_pos is not None
                  else jnp.arange(k.shape[1]))
    else:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        kv_pos = (cache_kv_pos if cache_kv_pos is not None
                  else jnp.arange(k.shape[1]))

    kf = repeat_kv(k, n_heads)
    vf = repeat_kv(v, n_heads)

    # --- SPMD sharding constraints (DESIGN.md §6) ---
    mode = pctx.resolve_attn_shard(shard, n_heads)
    ba = pctx.batch_axes()
    q_spec = None
    decode = cache is not None and s == 1
    if mode != "none":
        if decode:
            # split-KV decode: cache sequence-sharded over 'model'
            q = pctx.constrain(q, ba, None, None, None)
            kf = pctx.constrain(kf, ba, "model", None, None)
            vf = pctx.constrain(vf, ba, "model", None, None)
        elif mode == "head":
            q_spec = (ba, None, "model", None)
            q = pctx.constrain(q, *q_spec)
            kf = pctx.constrain(kf, ba, None, "model", None)
            vf = pctx.constrain(vf, ba, None, "model", None)
        else:  # seq-parallel: queries sharded over sequence, KV gathered
            q_spec = (ba, "model", None, None)
            q = pctx.constrain(q, *q_spec)
            kf = pctx.constrain(kf, ba, None, None, None)
            vf = pctx.constrain(vf, ba, None, None, None)

    # chunked path only handles batch-shared positions; per-lane decode
    # (q_pos 2-D) is always tiny (s == 1) and never needs it
    if s * kf.shape[1] > CHUNK_THRESHOLD and q_pos.ndim == 1:
        o = attend_chunked(q, kf, vf, q_pos, kv_pos, causal, window,
                           q_spec=q_spec)
    else:
        o = attend_direct(q, kf, vf, q_pos, kv_pos, causal, window,
                          bf16_scores=bf16_scores)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cache is None:
        return out, {"k": k_new, "v": v_new}
    if paged:
        # the updated pools go back as-is (the page table addresses them);
        # pool sharding is deferred to a sharded variant of the paged
        # decode kernel (kernels/paged_attention.py)
        return out, {"k": pk, "v": pv}
    if mode != "none":
        k = pctx.constrain(k, ba, "model", None, None)
        v = pctx.constrain(v, ba, "model", None, None)
    return out, {"k": k, "v": v}


def cross_attention(p: dict, x: jax.Array, mem_k: jax.Array,
                    mem_v: jax.Array, *, n_heads: int,
                    q_pos: jax.Array) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (B, T, Kv, D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    kf = repeat_kv(mem_k, n_heads)
    vf = repeat_kv(mem_v, n_heads)
    kv_pos = jnp.arange(kf.shape[1])
    s = x.shape[1]
    if s * kf.shape[1] > CHUNK_THRESHOLD:
        o = attend_chunked(q, kf, vf, q_pos, kv_pos, causal=False, window=0)
    else:
        o = attend_direct(q, kf, vf, q_pos, kv_pos, causal=False, window=0)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def memory_kv(p: dict, memory: jax.Array) -> tuple:
    """Encoder-memory K/V for cross-attention (computed once at prefill)."""
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"])
    return k, v
