"""PartitionSpec trees for params / DSG state / caches / inputs.

Built by walking the pytree with key paths and applying per-family rules
(DESIGN.md §6).  A returned spec of P() means fully replicated.  All rules
collapse gracefully on a 1-device mesh.
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_map_with_path, DictKey

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Axes


def _path_names(path) -> tuple:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        else:
            out.append(str(k))
    return tuple(out)


def _div(n: int, shards: int) -> bool:
    return shards > 0 and n % shards == 0


def _attn_mode(cfg: ModelConfig, n_model: int) -> str:
    if n_model <= 1:
        return "none"
    if cfg.attn_shard != "auto":
        return cfg.attn_shard
    return "head" if cfg.n_heads % n_model == 0 else "seq"


def param_specs(params: dict, cfg: ModelConfig, ax: Axes,
                n_model: int) -> dict:
    """Sharding rules keyed on the parameter path.

    Leading stacked-layer dims (L / G / (G, M)) are always replicated; the
    rules below describe the trailing semantic dims.
    """
    m = ax.model if n_model > 1 else None
    mode = _attn_mode(cfg, n_model)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        lead = (None,) * (leaf.ndim - 2)     # layer-stack prefix dims

        # ---- embeddings / heads -------------------------------------
        if name in ("embed", "tok_embed"):
            return P(m, None) if _div(cfg.vocab, n_model) else P()
        if name == "lm_head":
            return P(None, m) if _div(cfg.vocab, n_model) else P()
        # ---- norms / scalars ----------------------------------------
        if name in ("scale", "bias", "a_log", "dt_bias", "d_skip",
                    "b_gates", "skip", "r_diag", "conv_w", "router"):
            return P()
        # ---- attention ----------------------------------------------
        if names[-2] in ("attn", "cross") or (name in ("wq", "wk", "wv",
                                                       "wo")):
            if mode == "head":
                if name == "wq":
                    return P(*lead[:-1], None, m, None)
                if name in ("wk", "wv"):
                    ok = _div(cfg.n_kv, n_model)
                    return P(*lead[:-1], None, m, None) if ok else P()
                if name == "wo":
                    return P(*lead[:-1], m, None, None)
            return P()   # seq mode: weights replicated, activations S-sharded
        # ---- FFN (dense swiglu / gelu; also zamba shared) ------------
        if name in ("w_gate", "w_up") and leaf.ndim - len(lead) == 2 \
                and "moe" not in names:
            f = leaf.shape[-1]
            return P(*lead, None, m) if _div(f, n_model) else P()
        if name == "w_down" and "moe" not in names:
            f = leaf.shape[-2]
            return P(*lead, m, None) if _div(f, n_model) else P()
        # ---- MoE ------------------------------------------------------
        if "moe" in names and "shared" in names:
            if name in ("w_gate", "w_up"):
                return P(*lead, None, m) if _div(leaf.shape[-1], n_model) else P()
            if name == "w_down":
                return P(*lead, m, None) if _div(leaf.shape[-2], n_model) else P()
        if "moe" in names and name in ("w_gate", "w_up", "w_down"):
            e = leaf.shape[len(lead) - 1] if leaf.ndim >= 3 else 0
            # (L, E, d, f): experts over 'model' (EP)
            return P(*lead[:-1], m, None, None) if _div(e, n_model) else P()
        # ---- recurrent-family projections (row/col parallel) ---------
        if name in ("w_z", "w_x"):   # mamba2 head-parallel: columns over
            # 'model' -> gate/conv/SSM core all run head-sharded
            return P(*lead, None, m) if _div(leaf.shape[-1], n_model) else P()
        if name == "conv_x":         # depthwise conv follows its channels
            return P(*lead, None, m) if _div(leaf.shape[-1], n_model) else P()
        if name in ("w_bcdt", "conv_bc"):
            return P()
        if name == "w_in":           # (.., d, E_out): row-parallel over d
            return P(*lead, m, None) if _div(leaf.shape[-2], n_model) else P()
        if name in ("w_qkv",):
            return P(*lead, m, None) if _div(leaf.shape[-2], n_model) else P()
        if name in ("w_out", "w_gates"):
            return P(*lead, m, None) if _div(leaf.shape[-2], n_model) else P()
        return P()

    return tree_map_with_path(rule, params)


def dsg_specs(dsg: Optional[dict], cfg: ModelConfig, ax: Axes,
              n_model: int) -> Optional[dict]:
    if dsg is None:
        return None
    m = ax.model if n_model > 1 else None

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name == "r":
            return P()
        if name == "fw_experts":      # (L, E, k, fe): follow experts
            e = leaf.shape[1]
            return P(None, m, None, None) if _div(e, n_model) else P()
        # (.., k, F): follow FFN column sharding when F divides
        f = leaf.shape[-1]
        lead = (None,) * (leaf.ndim - 2)
        return P(*lead, None, m) if _div(f, n_model) else P()

    return tree_map_with_path(rule, dsg)


def cache_specs(cache, cfg: ModelConfig, ax: Axes, n_model: int):
    """Decode caches: KV sequence-sharded over 'model' (split-KV decode);
    recurrent states batch-sharded only."""
    if cache is None:
        return None
    m = ax.model if n_model > 1 else None
    b = ax.batch

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k", "v"):
            lead = (None,) * (leaf.ndim - 4)
            # (..., B, S, Kv, D)
            return P(*lead, b, m, None, None)
        # recurrent states: (..., B, ...) with leading stack dims
        if name in ("ssm", "conv_x", "conv_bc", "m_c", "m_n",
                    "c", "n", "m", "h"):
            idx = {"ssm": 2, "conv_x": 2, "conv_bc": 2,
                   "m_c": 2, "m_n": 2}.get(name, None)
            if idx is None:
                # xlstm slstm states (G, B, d) or (B, d)
                idx = leaf.ndim - 2
            lead = [None] * leaf.ndim
            lead[idx] = b
            return P(*lead)
        return P()

    return tree_map_with_path(rule, cache)


def input_specs(batch: dict, cfg: ModelConfig, ax: Axes) -> dict:
    b = ax.batch

    def rule(path, leaf):
        # all inputs are batch-major
        return P(b, *([None] * (leaf.ndim - 1)))

    return tree_map_with_path(rule, batch)
