"""Mixture-of-Experts FFN with expert parallelism (EP) over the 'model' axis.

Dispatch is capacity-based gather/scatter (no (T, E, C) one-hot einsum —
that tensor is quadratically too large at pod scale): tokens are assigned a
slot (expert, position) via a cumulative count, gathered into (E_local, C, d)
buffers, run through the expert matmuls, and scattered back weighted by the
router probability.  Tokens over capacity are dropped (standard Switch/GShard
behavior, capacity_factor controls headroom).

EP: expert weights are sharded over 'model'; the routed-FFN body runs inside
shard_map — every shard processes all of its data-parallel tokens for its
E/model_shards local experts, then a psum over 'model' combines expert
contributions (a token's top-k experts can live on different shards).

DSG composes *inside* each expert (DESIGN.md §3): per-expert f(W) buffers
estimate the expert's gate pre-activations and mask neuron groups — routing
gives coarse dynamic sparsity, DSG adds fine-grained intra-expert sparsity.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import drs, masks
from repro.core.dsg_linear import DSGConfig, init_swiglu, swiglu_ffn
from repro.models.layers import dense_init


def init_moe(key: jax.Array, d: int, n_experts: int, d_ff_e: int,
             n_shared: int, dtype=jnp.float32) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    keys = jax.random.split(ke, 3)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff_e)
    p = {
        "router": dense_init(kr, (d, n_experts), fan_in=d, dtype=jnp.float32),
        "w_gate": (jax.random.normal(keys[0], (n_experts, d, d_ff_e)) * sc_in).astype(dtype),
        "w_up": (jax.random.normal(keys[1], (n_experts, d, d_ff_e)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(keys[2], (n_experts, d_ff_e, d)) * sc_out).astype(dtype),
    }
    if n_shared > 0:
        p["shared"] = init_swiglu(ks, d, n_shared * d_ff_e, dtype=dtype)
    return p


def _routed_body(x2d: jax.Array, logits: jax.Array, w_gate: jax.Array,
                 w_up: jax.Array, w_down: jax.Array, e_start: jax.Array,
                 n_experts: int, top_k: int, capacity: int,
                 dsg_fw: Optional[jax.Array], dsg_r: Optional[jax.Array],
                 dsg: DSGConfig) -> jax.Array:
    """Per-shard routed-expert compute.  x2d (T, d); expert weights are the
    E_local local experts starting at global index e_start."""
    t, d = x2d.shape
    e_local = w_gate.shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)    # (T, E)
    top_w, top_e = jax.lax.top_k(probs, top_k)                     # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                     # (T*K,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    local_e = flat_e - e_start
    is_local = (local_e >= 0) & (local_e < e_local)
    local_e = jnp.where(is_local, local_e, e_local)                # sentinel

    # position of each entry within its expert queue (counts over T*K order)
    onehot = jax.nn.one_hot(local_e, e_local, dtype=jnp.int32)     # (T*K, E_l)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos * onehot, axis=-1)                           # (T*K,)
    in_cap = is_local & (pos < capacity)
    slot_e = jnp.where(in_cap, local_e, e_local)                   # drop o.o.b.
    slot_p = jnp.where(in_cap, pos, 0)

    idx_buf = jnp.full((e_local + 1, capacity), t, dtype=jnp.int32)
    idx_buf = idx_buf.at[slot_e, slot_p].set(flat_tok, mode="drop")
    w_buf = jnp.zeros((e_local + 1, capacity), dtype=jnp.float32)
    w_buf = w_buf.at[slot_e, slot_p].set(flat_w, mode="drop")
    idx_buf, w_buf = idx_buf[:e_local], w_buf[:e_local]

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xg = x_pad[idx_buf]                                            # (E_l, C, d)
    g = jnp.einsum("ecd,edf->ecf", xg, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xg, w_up)
    h = jax.nn.silu(g) * u
    if dsg.enabled and dsg_fw is not None:
        # per-expert DRS: f(X) @ f(W_e) -> group mask over the expert's F dim
        fx = jnp.einsum("ecd,kd->eck", xg, dsg_r)
        virtual = jnp.einsum("eck,ekf->ecf", fx, dsg_fw)
        scores = drs.group_scores(virtual, dsg.drs_cfg())
        mask, _ = drs.select_mask(scores, h.shape[-1], dsg.drs_cfg())
        h = masks.apply_expanded(h, masks.freeze(mask), dsg.block)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)                      # (E_l, C, d)

    out = jnp.zeros((t + 1, d), jnp.float32)
    out = out.at[idx_buf.reshape(-1)].add(
        (y * w_buf[..., None]).reshape(-1, d).astype(jnp.float32))
    return out[:t].astype(x2d.dtype)


def aux_load_balance_loss(logits: jax.Array, top_e: jax.Array,
                          n_experts: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0].reshape(-1), n_experts), axis=0)
    return n_experts * jnp.sum(me * ce)


def aux_probs_loss(logits: jax.Array, n_experts: int) -> jax.Array:
    """Sort-free load-balance surrogate: n_E * sum(mean_prob^2) — minimized
    by a uniform router, no top-k/argmax needed (the global top_k in the
    'topk' variant forces the SPMD partitioner to replicate the (T, E)
    probabilities across the data axes: EXPERIMENTS.md §Perf B1)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    return n_experts * jnp.sum(me * me)


def moe_ffn(p: dict, x: jax.Array, *, n_experts: int, top_k: int,
            capacity_factor: float, dsg: DSGConfig,
            dsg_state: Optional[dict] = None,
            mesh: Optional[Mesh] = None,
            batch_axes=None, aux_kind: str = "topk") -> tuple:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    With a mesh carrying a 'model' axis, the routed body runs under
    shard_map (EP); otherwise it runs locally with all experts.
    """
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    logits = x2d.astype(jnp.float32) @ p["router"]
    if aux_kind == "probs":
        aux = aux_probs_loss(logits, n_experts)
    else:
        _, top_e = jax.lax.top_k(jax.nn.softmax(logits, -1), top_k)
        aux = aux_load_balance_loss(logits, top_e, n_experts)

    dsg_r = dsg_state["r"] if (dsg.enabled and dsg_state) else None
    dsg_fw = dsg_state["fw_experts"] if (dsg.enabled and dsg_state) else None

    use_ep = mesh is not None and "model" in mesh.axis_names and \
        mesh.shape["model"] > 1 and n_experts % mesh.shape["model"] == 0
    if use_ep:
        n_shards = mesh.shape["model"]
        e_local = n_experts // n_shards
        t_local = x2d.shape[0] // max(
            1, math.prod(mesh.shape[a] for a in batch_axes or ()))
        capacity = max(1, int(capacity_factor * t_local * top_k / n_experts))

        def body(x_l, lg_l, wg, wu, wd, fw):
            e_start = jax.lax.axis_index("model") * e_local
            out = _routed_body(x_l, lg_l, wg, wu, wd, e_start, n_experts,
                               top_k, capacity, fw, dsg_r, dsg)
            return jax.lax.psum(out, "model")

        bspec = P(batch_axes, None)
        espec = P("model", None, None)
        fw_in = dsg_fw if dsg_fw is not None else \
            jnp.zeros((n_experts, 1, 1), x.dtype)
        y2d = shard_map(
            body, mesh=mesh,
            in_specs=(bspec, bspec, espec, espec, espec, espec),
            out_specs=bspec,
        )(x2d, logits, p["w_gate"], p["w_up"], p["w_down"], fw_in)
    else:
        capacity = max(1, int(capacity_factor * x2d.shape[0] * top_k
                              / n_experts))
        y2d = _routed_body(x2d, logits, p["w_gate"], p["w_up"], p["w_down"],
                           jnp.int32(0), n_experts, top_k, capacity,
                           dsg_fw, dsg_r, dsg)

    y = y2d.reshape(b, s, d)
    if "shared" in p:
        sh_state = dsg_state.get("shared") if dsg_state else None
        y = y + swiglu_ffn(p["shared"], x, sh_state, dsg)
    return y, aux
