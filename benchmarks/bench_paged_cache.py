"""Dense vs paged KV-cache backend: resident cache bytes + throughput.

The dense backend reserves the worst-case (L, n_slots, max_seq, Kv, D)
block no matter what traffic looks like; the paged backend
(serving/kv_cache.py) keeps a page pool sized to peak concurrent demand
and maps lanes onto it through a page table, so mixed traffic whose
prompt+generation lengths sit well under max_seq holds far fewer cache
bytes resident.  Both engines run the SAME traffic (threshold_mode="topk"
so lanes are computationally independent) and must produce identical
outputs — the run doubles as an end-to-end equivalence check, which is
why CI runs it with --smoke.

Default shape: max_seq=256 with prompts up to 64 and generations up to 32
(mean prompt+gen well under 96), pool sized to n_slots * (64 + 32) tokens
-> >= 2x fewer resident bytes than dense with zero admission deferrals.

  PYTHONPATH=src python benchmarks/bench_paged_cache.py --smoke
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.models import api
from repro.serving.scheduler import bucket_sizes
from repro.serving.workload import mixed_requests, run_workload


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    # per-row DRS selection: lanes are independent, so dense and paged
    # engines must agree token-for-token (see tests/test_serving_overlap.py)
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    # pool covering peak concurrent demand: every lane simultaneously at
    # its largest bucket + generation budget — no admission deferrals, and
    # still a fraction of the dense n_slots * max_seq reservation
    largest = bucket_sizes(args.prompt_bucket, args.max_seq)[-1]
    peak_lane = min(largest + args.gen_max, args.max_seq)
    cache_tokens = args.cache_tokens or args.slots * peak_lane

    results = {}
    for backend in ("dense", "paged"):
        reqs = mixed_requests(
            cfg.vocab, args.requests, seed=args.seed,
            prompt_range=(args.prompt_min, args.prompt_max),
            max_new_range=(args.gen_min, args.gen_max))
        st = run_workload(
            cfg, params, dsg, reqs, admission="overlap",
            n_slots=args.slots, max_seq=args.max_seq,
            prompt_bucket=args.prompt_bucket, cache_backend=backend,
            page_size=args.page_size,
            cache_tokens=cache_tokens if backend == "paged" else None)
        st["outputs"] = {r.uid: list(r.output) for r in reqs}
        results[backend] = st
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--cache-tokens", type=int, default=None,
                    help="paged pool capacity (default: slots * "
                         "(largest bucket + gen-max), the peak demand)")
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=64)
    ap.add_argument("--prompt-bucket", type=int, default=64)
    ap.add_argument("--gen-min", type=int, default=8)
    ap.add_argument("--gen-max", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    results = run(args)
    print(f"{'backend':>8} {'cache MB':>9} {'tok/s':>9} {'decode tok/s':>13} "
          f"{'steps':>6} {'tokens':>7}")
    for name, st in results.items():
        print(f"{name:>8} {st['cache_bytes'] / 1e6:>9.2f} "
              f"{st['tok_per_s']:>9.1f} {st['decode_tok_per_s']:>13.1f} "
              f"{st['steps']:>6d} {st['tokens']:>7d}")

    # explicit raises, not asserts: these are the CI regression gates and
    # must survive python -O
    if results["dense"]["outputs"] != results["paged"]["outputs"]:
        raise SystemExit(
            "FAIL: paged backend outputs diverge from the dense engine")
    ratio = results["dense"]["cache_bytes"] / results["paged"]["cache_bytes"]
    print(f"resident cache bytes: dense / paged = {ratio:.2f}x")
    if ratio < 2.0:
        raise SystemExit(f"FAIL: paged cache must hold >= 2x fewer resident "
                         f"bytes (got {ratio:.2f}x)")
    print("outputs identical across backends ✓")


if __name__ == "__main__":
    main()
