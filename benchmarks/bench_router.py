"""Front-end router benchmarks: policy gate + executor gate.

**Policy gate** (default): round_robin vs least_queue on skewed traffic.
Every `n_replicas`-th request is HEAVY (long prompt, 40-56 generated
tokens) and the rest are light (2-4 tokens) — the bursty pattern where
static round-robin assignment collides every heavy request onto the same
replica, which then grinds alone while its siblings sit idle.  The
queue-depth-aware `least_queue` policy dispatches lazily (only to a
replica with an uncommitted free lane), so fast replicas pull queued work
the moment they drain and the heavy tail spreads by live load.

The policy comparison runs on the SEQUENTIAL executor: replicas are
stepped one after another in one process, so raw wall clock would hide
the routing win (total work is identical by construction — the
differential check below asserts the merged greedy token streams agree
token-for-token).  The reported number is the MODELED data-parallel rate:
per-replica busy time is recorded by the executor, the makespan is the
slowest replica's busy time (what N truly parallel replica groups would
take), and parallel tok/s = total tokens / makespan — the same
record-then-model discipline as bench_paged_decode's HBM-bytes gate.

**Executor gate** (`--exec-mode threaded` / `sharded`): sequential vs
parallel execution of the same router under round_robin (identical
placement either way — a controlled execution-strategy comparison; see
run_exec_gate's docstring), now on MEASURED wall clock — the drain time
of the real run, no modeling.  The
traffic stays skewed (every 2nd request heavy) but the skew is in
GENERATION length, not prompt length, so the window is decode-dominated
steady state (admission prefills saturate a small host's cores and would
blur what the executor changes).  Merged streams must be identical
across executors; the gate is threaded >= 1.2x sequential measured
tok/s.

Gates (CI, smoke mode): least_queue >= 1.15x round_robin modeled
parallel tok/s (in practice ~1.8-2x), threaded >= 1.2x sequential
measured tok/s.  Emits BENCH_router.json.

  PYTHONPATH=src python benchmarks/bench_router.py --smoke
  PYTHONPATH=src python benchmarks/bench_router.py --smoke \
      --exec-mode threaded
"""
from __future__ import annotations

import argparse
import time

import jax

from common import bench_envelope, gate, write_bench

from repro import configs
from repro.models import api
from repro.serving.router import Router
from repro.serving.workload import skewed_requests, warmup_router


def _reset(router: Router):
    """Steady-state reset between repeats (the engines stay compiled)."""
    for eng in router.replicas:
        eng.done.clear()
        eng.steps = 0
        eng.decode_seconds = 0.0
        eng.decode_tokens = 0
    router.reset_counters()


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    results = {}
    for policy in ("round_robin", "least_queue"):
        router = Router(cfg, params, dsg, n_replicas=args.replicas,
                        policy=policy, n_slots=args.slots,
                        max_seq=args.max_seq,
                        prompt_bucket=args.prompt_bucket,
                        cache_backend=args.cache_backend,
                        page_size=args.page_size, seed=args.seed)
        warmup_router(router, cfg.vocab)
        best = None
        for _ in range(args.repeats):
            _reset(router)
            # identical traffic for both policies (fresh Request objects)
            reqs = skewed_requests(cfg.vocab, args.requests,
                                   period=args.replicas, seed=args.seed)
            for r in reqs:
                router.submit(r)
            done = router.run(max_steps=100_000)
            if len(done) != len(reqs):
                raise SystemExit(f"FAIL: {policy} finished {len(done)} of "
                                 f"{len(reqs)} requests")
            toks = sum(len(r.output) for r in done.values())
            makespan = router.makespan_seconds()
            st = {
                "tokens": toks,
                "makespan_s": makespan,
                "parallel_tok_per_s": toks / max(makespan, 1e-9),
                "busy_s": list(router.busy_seconds),
                "replica_tokens": [e.decode_tokens
                                   for e in router.replicas],
                "heavy_per_replica": [
                    sum(1 for u, r in router.dispatch_log
                        if r == i and u % args.replicas == 0)
                    for i in range(args.replicas)],
                "outputs": {u: list(r.output) for u, r in done.items()},
            }
            if best is None or (st["parallel_tok_per_s"]
                                > best["parallel_tok_per_s"]):
                best = st      # best-of-N: washes out host timing noise
        results[policy] = best
    return results


def run_exec_gate(args) -> dict:
    """Sequential vs parallel executor on decode-heavy skewed traffic.

    Same requests, same policy, two executors; tok/s here is tokens /
    MEASURED drain wall clock (perf_counter around run()), so the
    comparison is end-to-end real time, dispatch overhead included.
    Repeats interleave the two executors and the gate ratio is the BEST
    per-repeat paired ratio (the policy gate's best-of-N discipline,
    applied to pairs): adjacent measurements share machine state (CPU
    frequency, allocator, thermal drift), so a pair's ratio reflects
    the executors and not the drift — while comparing each side's best
    across the whole run lets one lucky serialized-baseline repeat
    decide the gate.  Host scheduling on a small box can still halve
    the overlap in any single pair (observed paired ratios: ~1.1-1.45),
    so the gate asks whether fair paired measurement REACHES the
    speedup, not whether every draw does; the full ratio list is
    printed and lands in the JSON payload.

    The policy is round_robin on purpose: it dispatches unconditionally,
    so placement is identical under both executors (a controlled
    execution-strategy comparison — pull-based policies re-decide
    against live timing) and every engine holds its full queue up front
    (a pull policy's dispatch-to-admission latency would idle lanes only
    in the parallel mode and muddy the measurement).  The heavy period
    is 3 against 2 replicas, so heavy generations alternate replicas
    instead of funneling onto one — both replicas stay busy, which is
    the regime where overlap shows.

    `--exec-scale` widens the smoke model (d_model, d_ff): the stock
    smoke config is dispatch-bound — a decode step is mostly GIL-held
    Python, which threads cannot overlap — so the gate scales the model
    until a step carries enough GIL-free device compute for overlap to
    be measurable.  Real configs on real accelerators are in that
    regime natively."""
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    if args.smoke and args.exec_scale > 1:
        cfg = cfg.replace(d_model=cfg.d_model * args.exec_scale,
                          d_ff=cfg.d_ff * args.exec_scale)
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    def traffic(seed):
        # skew lives in max_new: heavy generations, light prompts, so
        # the measured window is decode steps, not admission prefills
        return skewed_requests(cfg.vocab, args.exec_requests, period=3,
                               seed=seed,
                               heavy_prompt=(24, 30), heavy_new=(40, 56),
                               light_prompt=(8, 16), light_new=(8, 16))

    # both routers live for the whole measurement and their repeats
    # INTERLEAVE: measuring one mode's repeats in a block and then the
    # other's lets slow process-level drift (CPU frequency, allocator
    # state) land entirely on one side and flip the ratio run-to-run —
    # interleaved, the same drift hits both modes equally
    modes = ("sequential", args.exec_mode)
    routers = {
        mode: Router(cfg, params, dsg, n_replicas=args.replicas,
                     policy="round_robin", exec_mode=mode,
                     n_slots=args.exec_slots,
                     max_seq=args.exec_max_seq,
                     prompt_bucket=args.exec_prompt_bucket,
                     cache_backend=args.cache_backend,
                     page_size=args.page_size, seed=args.seed)
        for mode in modes}
    for router in routers.values():
        warmup_router(router, cfg.vocab)
    results = {}
    ratios = []
    for rep in range(args.exec_repeats):
        pair = {}
        for mode in modes:
            router = routers[mode]
            _reset(router)
            reqs = traffic(args.seed)
            for r in reqs:
                router.submit(r)
            t0 = time.perf_counter()
            done = router.run(max_steps=100_000)
            wall = time.perf_counter() - t0
            if len(done) != len(reqs):
                raise SystemExit(f"FAIL: {mode} finished {len(done)} of "
                                 f"{len(reqs)} requests")
            toks = sum(len(r.output) for r in done.values())
            st = {
                "tokens": toks,
                "wall_s": wall,
                "tok_per_s": toks / max(wall, 1e-9),
                "makespan_s": router.makespan_seconds(),
                "makespan_measured": router.executor.measured,
                "outputs": {u: list(r.output) for u, r in done.items()},
            }
            pair[mode] = st["tok_per_s"]
            best = results.get(mode)
            if best is None or st["tok_per_s"] > best["tok_per_s"]:
                results[mode] = st
        ratios.append(pair[args.exec_mode] / pair["sequential"])
    for router in routers.values():
        router.close()
    results["paired_ratios"] = sorted(ratios)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--prompt-bucket", type=int, default=192)
    ap.add_argument("--cache-backend", choices=("dense", "paged"),
                    default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--exec-mode", choices=("threaded", "sharded"),
                    default=None,
                    help="run the executor gate instead of the policy "
                         "gate: sequential vs this executor, measured "
                         "wall clock, round_robin placement")
    ap.add_argument("--exec-slots", type=int, default=4)
    ap.add_argument("--exec-max-seq", type=int, default=128)
    ap.add_argument("--exec-prompt-bucket", type=int, default=32)
    ap.add_argument("--exec-repeats", type=int, default=5)
    ap.add_argument("--exec-requests", type=int, default=24,
                    help="request count for the executor gate (longer "
                         "steady-state window than the policy gate's "
                         "--requests)")
    ap.add_argument("--exec-scale", type=int, default=6,
                    help="widen the smoke model (d_model, d_ff) for the "
                         "executor gate so a decode step carries enough "
                         "device compute to overlap (smoke only)")
    ap.add_argument("--exec-gate", type=float, default=1.2,
                    help="minimum threaded/sequential best-paired ratio "
                         "(diagnostic override; CI enforces the default)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.exec_mode is not None:
        out = args.out or "BENCH_router_exec.json"
        t0 = time.time()
        results = run_exec_gate(args)
        ratios = results.pop("paired_ratios")
        print(f"{'executor':>12} {'tok/s':>8} {'wall s':>8} "
              f"{'makespan s':>11} {'measured':>9}")
        for name, st in results.items():
            print(f"{name:>12} {st['tok_per_s']:>8.1f} "
                  f"{st['wall_s']:>8.2f} {st['makespan_s']:>11.2f} "
                  f"{str(st['makespan_measured']):>9}")
        streams_ok = (results["sequential"]["outputs"]
                      == results[args.exec_mode]["outputs"])
        speedup = ratios[-1]                   # best paired ratio
        payload = {name: {k: v for k, v in st.items() if k != "outputs"}
                   for name, st in results.items()}
        payload["paired_ratios"] = ratios
        payload[f"{args.exec_mode}_vs_sequential"] = speedup
        payload["config"] = {"replicas": args.replicas,
                             "slots": args.exec_slots,
                             "requests": args.exec_requests,
                             "exec_scale": args.exec_scale,
                             "max_seq": args.exec_max_seq,
                             "prompt_bucket": args.exec_prompt_bucket,
                             "cache_backend": args.cache_backend,
                             "exec_mode": args.exec_mode}
        gates = [gate(f"{args.exec_mode} executor merged streams match "
                      f"sequential", 1.0, float(streams_ok), streams_ok)]
        if args.exec_mode == "threaded":   # sharded ratio is diagnostic
            gates.append(gate(
                f"threaded >= {args.exec_gate}x sequential measured "
                f"tok/s (best paired repeat)", args.exec_gate, speedup,
                speedup >= args.exec_gate))
        # write first: a red run leaves a diagnosable artifact
        write_bench(out, bench_envelope(
            "router_exec", gates=gates, ratio=speedup, t_start=t0,
            results=payload))
        # explicit raises, not asserts: CI gates, survive python -O
        if not streams_ok:
            raise SystemExit(
                f"FAIL: {args.exec_mode} executor emits diverging merged "
                f"token streams (executor invariance broken)")
        print(f"merged greedy streams identical across executors ✓")
        print(f"{args.exec_mode} / sequential measured throughput: "
              f"{speedup:.2f}x (best paired repeat; all: "
              f"{' '.join(f'{r:.2f}' for r in ratios)})")
        if args.exec_mode == "threaded" and speedup < args.exec_gate:
            raise SystemExit(
                f"FAIL: threaded executor must reach >= "
                f"{args.exec_gate}x sequential measured tok/s on skewed "
                f"traffic (got {speedup:.2f}x)")
        return

    out = args.out or "BENCH_router.json"
    t0 = time.time()
    results = run(args)
    print(f"{'policy':>12} {'par tok/s':>10} {'makespan s':>11} "
          f"{'busy s/replica':>24} {'heavy/replica':>14}")
    for name, st in results.items():
        busy = " ".join(f"{b:.2f}" for b in st["busy_s"])
        heavy = " ".join(str(h) for h in st["heavy_per_replica"])
        print(f"{name:>12} {st['parallel_tok_per_s']:>10.1f} "
              f"{st['makespan_s']:>11.2f} {busy:>24} {heavy:>14}")

    streams_ok = (results["round_robin"]["outputs"]
                  == results["least_queue"]["outputs"])
    speedup = (results["least_queue"]["parallel_tok_per_s"]
               / results["round_robin"]["parallel_tok_per_s"])
    payload = {name: {k: v for k, v in st.items() if k != "outputs"}
               for name, st in results.items()}
    payload["least_queue_vs_round_robin"] = speedup
    payload["config"] = {"replicas": args.replicas, "slots": args.slots,
                         "requests": args.requests,
                         "cache_backend": args.cache_backend}
    gates = [
        gate("routing policies emit identical merged token streams",
             1.0, float(streams_ok), streams_ok),
        gate("least_queue >= 1.15x round_robin modeled parallel tok/s "
             "on skewed traffic", 1.15, speedup, speedup >= 1.15),
    ]
    # write first: a red run leaves a diagnosable artifact
    write_bench(out, bench_envelope(
        "router", gates=gates, ratio=speedup, t_start=t0,
        results=payload))

    # explicit raises, not asserts: CI regression gates, survive python -O
    if not streams_ok:
        raise SystemExit(
            "FAIL: routing policies emit diverging merged token streams "
            "(replica-count invariance broken)")
    print("merged greedy streams identical across policies ✓")
    print(f"least_queue / round_robin parallel throughput: {speedup:.2f}x")
    if speedup < 1.15:
        raise SystemExit(
            f"FAIL: least_queue must reach >= 1.15x round_robin parallel "
            f"tok/s on skewed traffic (got {speedup:.2f}x)")


if __name__ == "__main__":
    main()
