"""Front-end router policies: round_robin vs least_queue on skewed traffic.

Every `n_replicas`-th request is HEAVY (long prompt, 40-56 generated
tokens) and the rest are light (2-4 tokens) — the bursty pattern where
static round-robin assignment collides every heavy request onto the same
replica, which then grinds alone while its siblings sit idle.  The
queue-depth-aware `least_queue` policy dispatches lazily (only to a
replica with an uncommitted free lane), so fast replicas pull queued work
the moment they drain and the heavy tail spreads by live load.

Replicas are stepped sequentially in one process, so raw wall clock would
hide the routing win (total work is identical by construction — the
differential check below asserts the merged greedy token streams agree
token-for-token).  The reported number is the MODELED data-parallel rate:
per-replica busy time is recorded by the router, the makespan is the
slowest replica's busy time (what N truly parallel replica groups would
take), and parallel tok/s = total tokens / makespan — the same
record-then-model discipline as bench_paged_decode's HBM-bytes gate.

Gate (CI, smoke mode): least_queue >= 1.15x round_robin parallel tok/s;
in practice the skewed pattern sits near 1.8-2x.  Emits BENCH_router.json.

  PYTHONPATH=src python benchmarks/bench_router.py --smoke
"""
from __future__ import annotations

import argparse
import json

import jax

from repro import configs
from repro.models import api
from repro.serving.router import Router
from repro.serving.workload import skewed_requests, warmup_router


def _reset(router: Router):
    """Steady-state reset between repeats (the engines stay compiled)."""
    for eng in router.replicas:
        eng.done.clear()
        eng.steps = 0
        eng.decode_seconds = 0.0
        eng.decode_tokens = 0
    router.reset_counters()


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    results = {}
    for policy in ("round_robin", "least_queue"):
        router = Router(cfg, params, dsg, n_replicas=args.replicas,
                        policy=policy, n_slots=args.slots,
                        max_seq=args.max_seq,
                        prompt_bucket=args.prompt_bucket,
                        cache_backend=args.cache_backend,
                        page_size=args.page_size, seed=args.seed)
        warmup_router(router, cfg.vocab)
        best = None
        for _ in range(args.repeats):
            _reset(router)
            # identical traffic for both policies (fresh Request objects)
            reqs = skewed_requests(cfg.vocab, args.requests,
                                   period=args.replicas, seed=args.seed)
            for r in reqs:
                router.submit(r)
            done = router.run(max_steps=100_000)
            if len(done) != len(reqs):
                raise SystemExit(f"FAIL: {policy} finished {len(done)} of "
                                 f"{len(reqs)} requests")
            toks = sum(len(r.output) for r in done.values())
            makespan = router.makespan_seconds()
            st = {
                "tokens": toks,
                "makespan_s": makespan,
                "parallel_tok_per_s": toks / max(makespan, 1e-9),
                "busy_s": list(router.busy_seconds),
                "replica_tokens": [e.decode_tokens
                                   for e in router.replicas],
                "heavy_per_replica": [
                    sum(1 for u, r in router.dispatch_log
                        if r == i and u % args.replicas == 0)
                    for i in range(args.replicas)],
                "outputs": {u: list(r.output) for u, r in done.items()},
            }
            if best is None or (st["parallel_tok_per_s"]
                                > best["parallel_tok_per_s"]):
                best = st      # best-of-N: washes out host timing noise
        results[policy] = best
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--prompt-bucket", type=int, default=192)
    ap.add_argument("--cache-backend", choices=("dense", "paged"),
                    default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_router.json")
    args = ap.parse_args()

    results = run(args)
    print(f"{'policy':>12} {'par tok/s':>10} {'makespan s':>11} "
          f"{'busy s/replica':>24} {'heavy/replica':>14}")
    for name, st in results.items():
        busy = " ".join(f"{b:.2f}" for b in st["busy_s"])
        heavy = " ".join(str(h) for h in st["heavy_per_replica"])
        print(f"{name:>12} {st['parallel_tok_per_s']:>10.1f} "
              f"{st['makespan_s']:>11.2f} {busy:>24} {heavy:>14}")

    # explicit raises, not asserts: CI regression gates, survive python -O
    if results["round_robin"]["outputs"] != results["least_queue"]["outputs"]:
        raise SystemExit(
            "FAIL: routing policies emit diverging merged token streams "
            "(replica-count invariance broken)")
    print("merged greedy streams identical across policies ✓")
    speedup = (results["least_queue"]["parallel_tok_per_s"]
               / results["round_robin"]["parallel_tok_per_s"])
    print(f"least_queue / round_robin parallel throughput: {speedup:.2f}x")
    if speedup < 1.15:
        raise SystemExit(
            f"FAIL: least_queue must reach >= 1.15x round_robin parallel "
            f"tok/s on skewed traffic (got {speedup:.2f}x)")

    payload = {name: {k: v for k, v in st.items() if k != "outputs"}
               for name, st in results.items()}
    payload["least_queue_vs_round_robin"] = speedup
    payload["config"] = {"replicas": args.replicas, "slots": args.slots,
                         "requests": args.requests,
                         "cache_backend": args.cache_backend}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
