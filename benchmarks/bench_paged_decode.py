"""Paged decode executors: per-step latency + modeled decode KV HBM bytes.

Three engines run the SAME mixed traffic (threshold_mode="topk" so lanes
are independent and streams must agree token-for-token):

  * dense        — worst-case (L, n_slots, max_seq, Kv, D) cache; every
                   step streams the whole window for every lane.
  * paged-xla    — paged backend, XLA executor: scatter through the page
                   table, gather bounded at the scheduler's live-page
                   bucket (the satellite fix; the historical path
                   gathered the whole window every step).
  * paged-kernel — paged backend, Pallas kernel executor
                   (kernels/paged_attention.py): per lane, only pages at
                   or below that lane's depth are read.

Wall-clock per decode step is reported for all three, but on CPU the
kernel executor runs in interpret mode, so its latency column is not
meaningful off-TPU — the regression gate is the MODELED decode KV HBM
traffic, reconstructed exactly from the recorded per-step lane depths:

  dense / whole-window :  L * B * max_seq            rows per step
  paged-xla (bounded)  :  L * B * bucket_pages * ps  rows per step
  paged-kernel         :  L * sum_lanes (depth_pages_b * ps) rows
                          (+ one K and one V page write per lane)

where a row is Kv * D * itemsize bytes for each of K and V.  The gate:
kernel bytes <= 0.6x the whole-window paged path at max_seq=256 mixed
traffic — the ROADMAP's "cut decode HBM traffic roughly in half".

Emits BENCH_paged_decode.json; CI runs `--smoke` and fails on stream
divergence or a missed traffic gate.

  PYTHONPATH=src python benchmarks/bench_paged_decode.py --smoke
"""
from __future__ import annotations

import argparse
import time

import jax

from common import bench_envelope, gate, write_bench

from repro import configs
from repro.models import api
from repro.serving.scheduler import (ServingEngine, bucket_sizes,
                                     live_page_bound)
from repro.serving.workload import mixed_requests, warmup_engine


def _engine(cfg, params, dsg, args, backend, cache_tokens):
    return ServingEngine(cfg, params, dsg, n_slots=args.slots,
                         max_seq=args.max_seq, admission="overlap",
                         prompt_bucket=args.prompt_bucket,
                         cache_backend=backend, page_size=args.page_size,
                         cache_tokens=cache_tokens if backend == "paged"
                         else None)


def _run_recorded(cfg, params, dsg, args, backend, cache_tokens, reqs):
    """Drive one engine step-by-step, recording the pre-step per-lane
    depths (the decode write positions) for the traffic model.
    workload.warmup_engine compiles every prefill bucket and every
    live-page decode variant first, so the latency columns are
    steady-state."""
    eng = _engine(cfg, params, dsg, args, backend, cache_tokens)
    warmup_engine(eng, cfg.vocab)

    for r in reqs:
        eng.submit(r)
    depths = []      # per decode step: active lanes' write positions
    while eng.queue or any(not s.free for s in eng.slots):
        # admission happens inside step(); pre-admitting here (a no-op
        # when it re-runs) lets us record the exact pre-decode depths
        eng._admit()
        depths.append([s.pos for s in eng.slots if not s.free])
        eng.step()
        if eng.steps >= 100_000:    # explicit raise: survives python -O
            raise RuntimeError("engine failed to drain the workload")
    outputs = {r.uid: list(r.output) for r in reqs}
    return eng, depths, outputs


def _modeled_bytes(cfg, args, depths, mode):
    """Decode KV HBM bytes over the run, from recorded lane depths."""
    ps = args.page_size
    max_pages = args.max_seq // ps
    row = 2 * cfg.n_kv * cfg.head_dim * 4 * cfg.n_layers   # K+V, f32, L
    if cfg.dtype == "bfloat16":
        row //= 2
    total = 0
    for active in depths:
        if mode == "window":          # dense AND the old whole-window paged
            total += args.slots * args.max_seq * row
        elif mode == "bounded":       # XLA fallback at the scheduler bucket
            bucket = live_page_bound(max(active), ps, max_pages)
            total += args.slots * bucket * ps * row
        else:                         # kernel: per-lane depth-bounded walk
            # free lanes mirror the deepest... conservatively model every
            # lane at the donor depth it actually reads
            lanes = list(active) + [active[0]] * (args.slots - len(active))
            total += sum((p // ps + 1) * ps for p in lanes) * row
            total += args.slots * ps * row          # per-lane page write
    return total


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    largest = bucket_sizes(args.prompt_bucket, args.max_seq)[-1]
    peak_lane = min(largest + args.gen_max, args.max_seq)
    cache_tokens = args.cache_tokens or args.slots * peak_lane

    variants = (("dense", cfg, "dense", "window"),
                ("paged-xla", cfg.replace(paged_attn_kernel="xla"),
                 "paged", "bounded"),
                ("paged-kernel", cfg.replace(paged_attn_kernel="kernel"),
                 "paged", "kernel"))
    results = {}
    kernel_depths = None
    for name, vcfg, backend, mode in variants:
        reqs = mixed_requests(
            cfg.vocab, args.requests, seed=args.seed,
            prompt_range=(args.prompt_min, args.prompt_max),
            max_new_range=(args.gen_min, args.gen_max))
        eng, depths, outputs = _run_recorded(vcfg, params, dsg, args,
                                             backend, cache_tokens, reqs)
        if mode == "kernel":
            kernel_depths = depths
        total = _modeled_bytes(cfg, args, depths, mode)
        results[name] = {
            "steps": eng.steps,
            "tokens": eng.decode_tokens,
            "decode_ms_per_step": 1e3 * eng.decode_seconds
                                  / max(eng.steps, 1),
            "modeled_kv_mb": total / 1e6,
            "modeled_kv_mb_per_step": total / max(len(depths), 1) / 1e6,
            "outputs": outputs,
        }
    # the historical paged path read the whole window every step — the
    # baseline the kernel's traffic gate is measured against (same steps
    # as the kernel run: identical traffic, identical streams)
    results["paged-window-model"] = {
        "modeled_kv_mb": _modeled_bytes(cfg, args, kernel_depths,
                                        "window") / 1e6}
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--cache-tokens", type=int, default=None)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=64)
    ap.add_argument("--prompt-bucket", type=int, default=64)
    ap.add_argument("--gen-min", type=int, default=8)
    ap.add_argument("--gen-max", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_paged_decode.json")
    args = ap.parse_args()

    t0 = time.time()
    results = run(args)
    window_mb = results["paged-window-model"]["modeled_kv_mb"]
    print(f"{'variant':>13} {'ms/step':>9} {'KV MB/step':>11} "
          f"{'KV MB total':>12} {'steps':>6}")
    for name in ("dense", "paged-xla", "paged-kernel"):
        st = results[name]
        print(f"{name:>13} {st['decode_ms_per_step']:>9.2f} "
              f"{st['modeled_kv_mb_per_step']:>11.3f} "
              f"{st['modeled_kv_mb']:>12.2f} {st['steps']:>6d}")
    print(f"{'paged-window':>13} {'-':>9} {'-':>11} {window_mb:>12.2f} "
          f"  (historical whole-window gather)")

    streams_ok = (results["dense"]["outputs"]
                  == results["paged-xla"]["outputs"]
                  == results["paged-kernel"]["outputs"])
    ratio = results["paged-kernel"]["modeled_kv_mb"] / window_mb
    print(f"kernel / whole-window modeled KV bytes = {ratio:.3f}")

    payload = {k: {kk: vv for kk, vv in v.items() if kk != "outputs"}
               for k, v in results.items()}
    payload["kernel_vs_window_ratio"] = ratio
    gates = [
        gate("decode executors emit identical streams", 1.0,
             float(streams_ok), streams_ok),
        gate("paged kernel modeled decode KV HBM bytes <= 0.6x the "
             "whole-window gather", 0.6, ratio, ratio <= 0.6),
    ]
    # write first: a red run leaves a diagnosable artifact
    write_bench(args.out, bench_envelope(
        "paged_decode", gates=gates, ratio=ratio, t_start=t0,
        results=payload))

    # explicit raises, not asserts: CI regression gates, survive python -O
    if not streams_ok:
        raise SystemExit("FAIL: decode executors emit diverging streams")
    print("streams identical across executors ✓")
    if ratio > 0.6:
        raise SystemExit(
            f"FAIL: paged kernel must cut modeled decode KV HBM bytes to "
            f"<= 0.6x the whole-window gather (got {ratio:.3f}x)")


if __name__ == "__main__":
    main()
