"""Re-run the HLO analyzer over saved hlo.gz artifacts and refresh the
'analysis' block of each results JSON (no recompilation needed)."""
import glob
import gzip
import json
import os
import sys

from repro.launch import hlo_analysis


def main(results="results"):
    for jf in sorted(glob.glob(os.path.join(results, "*.json"))):
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        tag = rec.get("tag") or ("dsg" if rec.get("dsg", True) else "dense")
        hf = os.path.join(results, "hlo",
                          f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__"
                          f"{tag}.hlo.gz")
        if not os.path.exists(hf):
            continue
        with gzip.open(hf, "rt") as f:
            rec["analysis"] = hlo_analysis.analyze(f.read())
        json.dump(rec, open(jf, "w"), indent=1)
        print("reanalyzed", os.path.basename(jf))


if __name__ == "__main__":
    main(*sys.argv[1:])
