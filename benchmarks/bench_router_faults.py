"""Chaos gate for fault-tolerant serving: kill a replica mid-decode and
require bitwise-identical output plus bounded goodput loss.

The run drives identical mixed traffic through the same warmed Router
twice per repeat — a HEALTHY pass (no injector attached) and a CHAOS
pass where `ServingFaultInjector` kills replica 1 at a fixed engine step
with `max_replica_restarts=0`, so the replica stays DEAD and its queued +
in-flight requests replay on the 2 survivors.  Because greedy decode
under per-row DRS selection is solo-deterministic (the invariant pinned
since PR 1), replay-from-prompt must reproduce the healthy streams
bit-for-bit: stream divergence here means failover resumed a corrupted
partial instead of replaying.

Gates (CI, smoke mode; emits BENCH_router_faults.json):
  * every request completes with status "ok" despite the mid-run kill;
  * chaos merged streams are bitwise equal to the healthy pass;
  * goodput: chaos modeled parallel tok/s >= (survivors/replicas x 0.8)
    of the healthy baseline (best paired repeat — replay wastes the dead
    replica's partial work, so perfection is surviving-capacity scaled);
  * the injector fired its kill exactly once per chaos pass;
  * a deadline-expired request surfaces status "timed_out" and drain()
    returns (no hang) — the graceful-degradation contract.

  PYTHONPATH=src python benchmarks/bench_router_faults.py --smoke
"""
from __future__ import annotations

import argparse
import time

import jax

from common import bench_envelope, gate, write_bench

from repro import configs
from repro.models import api
from repro.runtime.fault_tolerance import ReplicaFault, ServingFaultInjector
from repro.serving.router import FaultToleranceConfig, Router
from repro.serving.scheduler import Request
from repro.serving.workload import mixed_requests, warmup_router


def _reset(router: Router):
    """Steady-state reset between repeats (the engines stay compiled)."""
    for eng in router.engines:
        eng.done.clear()
        eng.steps = 0
        eng.decode_seconds = 0.0
        eng.decode_tokens = 0
    router.reset_counters()
    router.reset_health()


def _drive(router, reqs):
    for r in reqs:
        router.submit(r)
    done = router.run(max_steps=100_000)
    toks = sum(len(r.output) for r in done.values())
    makespan = router.makespan_seconds()
    return {
        "requests": len(done),
        "completed_ok": sum(r.status == "ok" for r in done.values()),
        "retries": sum(r.retries for r in done.values()),
        "tokens": toks,
        "makespan_s": makespan,
        "parallel_tok_per_s": toks / max(makespan, 1e-9),
        "busy_s": list(router.busy_seconds),
        "replica_health": [h.state for h in router.health],
        "outputs": {u: list(r.output) for u, r in done.items()},
    }


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    # zero restarts: the killed replica stays dead, so the goodput gate
    # measures true degraded-capacity operation (survivors/replicas)
    router = Router(cfg, params, dsg, n_replicas=args.replicas,
                    policy="round_robin", n_slots=args.slots,
                    max_seq=args.max_seq, prompt_bucket=args.prompt_bucket,
                    cache_backend=args.cache_backend,
                    page_size=args.page_size, seed=args.seed,
                    fault_tolerance=FaultToleranceConfig(
                        max_replica_restarts=0, max_retries=args.replicas))
    warmup_router(router, cfg.vocab)
    injector = ServingFaultInjector(
        [ReplicaFault(replica=args.kill_replica, step=args.kill_step)])

    def traffic():
        return mixed_requests(cfg.vocab, args.requests, seed=args.seed,
                              prompt_range=(8, args.prompt_bucket),
                              max_new_range=(8, 40))

    # repeats interleave healthy/chaos so host drift hits both sides
    # equally; the goodput ratio is the BEST paired repeat (the
    # bench_router discipline)
    results = {}
    ratios = []
    faults_fired = []
    streams_matched = []
    for _ in range(args.repeats):
        pair = {}
        for mode in ("healthy", "chaos"):
            _reset(router)
            if mode == "chaos":
                injector.reset()
                injector.attach(router.engines)
            st = _drive(router, traffic())
            if mode == "chaos":
                injector.detach(router.engines)
                faults_fired.append(len(injector.log))
                streams_matched.append(
                    st["outputs"] == results["healthy"]["outputs"])
            pair[mode] = st["parallel_tok_per_s"]
            best = results.get(mode)
            if (best is None or st["parallel_tok_per_s"]
                    > best["parallel_tok_per_s"]):
                results[mode] = st
        ratios.append(pair["chaos"] / pair["healthy"])
    router.close()
    results["paired_ratios"] = sorted(ratios)
    results["faults_fired"] = faults_fired
    results["streams_matched"] = streams_matched

    # deadline pass: fill every lane with long generations, then submit a
    # request whose deadline expires while it waits in the router queue —
    # it must surface as timed_out, and drain must still return
    _reset(router)
    lanes = args.replicas * args.slots
    longs = mixed_requests(cfg.vocab, lanes, seed=args.seed + 1,
                           prompt_range=(8, 24), max_new_range=(40, 48))
    late = Request(uid=lanes,
                   prompt=longs[0].prompt.copy(), max_new=4,
                   deadline_s=1e-4)
    for r in longs:
        router.submit(r)
    router.submit(late)
    done = router.drain(max_steps=100_000)
    results["deadline"] = {
        "statuses": {u: r.status for u, r in sorted(done.items())},
        "timed_out_uid": late.uid,
        "drained": len(done) == lanes + 1,
    }
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--prompt-bucket", type=int, default=128)
    ap.add_argument("--cache-backend", choices=("dense", "paged"),
                    default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-replica", type=int, default=1)
    ap.add_argument("--kill-step", type=int, default=4,
                    help="engine step (post-warmup) at which the kill "
                         "fires — mid-decode for the default traffic")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out = args.out or "BENCH_router_faults.json"
    t0 = time.time()
    results = run(args)

    ratios = results.pop("paired_ratios")
    faults_fired = results.pop("faults_fired")
    streams_matched = results.pop("streams_matched")
    deadline = results["deadline"]
    print(f"{'pass':>8} {'ok':>5} {'tokens':>7} {'par tok/s':>10} "
          f"{'makespan s':>11} {'health':>26}")
    for name in ("healthy", "chaos"):
        st = results[name]
        print(f"{name:>8} {st['completed_ok']:>2}/{st['requests']:<2} "
              f"{st['tokens']:>7} {st['parallel_tok_per_s']:>10.1f} "
              f"{st['makespan_s']:>11.2f} "
              f"{' '.join(st['replica_health']):>26}")

    surviving = args.replicas - 1
    goodput_floor = surviving / args.replicas * 0.8
    goodput = ratios[-1]                        # best paired repeat
    all_ok = (results["chaos"]["completed_ok"]
              == results["chaos"]["requests"] == args.requests)
    streams_ok = bool(streams_matched) and all(streams_matched)
    fired_once = all(n == 1 for n in faults_fired)
    timed_out_ok = (deadline["drained"] and deadline["statuses"]
                    [deadline["timed_out_uid"]] == "timed_out")

    payload = {name: {k: v for k, v in st.items() if k != "outputs"}
               for name, st in results.items() if name != "deadline"}
    payload["deadline"] = deadline
    payload["paired_ratios"] = ratios
    payload["chaos_vs_healthy_goodput"] = goodput
    payload["faults_fired_per_repeat"] = faults_fired
    payload["streams_matched_per_repeat"] = streams_matched
    payload["config"] = {"replicas": args.replicas, "slots": args.slots,
                         "requests": args.requests,
                         "cache_backend": args.cache_backend,
                         "kill_replica": args.kill_replica,
                         "kill_step": args.kill_step}
    gates = [
        gate("every request completes ok despite mid-run replica kill",
             1.0, float(all_ok), all_ok),
        gate("chaos merged streams bitwise equal to healthy run",
             1.0, float(streams_ok), streams_ok),
        gate(f"chaos goodput >= {goodput_floor:.3f}x healthy "
             f"({surviving}/{args.replicas} survivors x 0.8, best paired "
             f"repeat)", goodput_floor, goodput, goodput >= goodput_floor),
        gate("kill fault fires exactly once per chaos pass",
             1.0, float(fired_once), fired_once),
        gate("deadline-expired request surfaces timed_out without "
             "hanging drain", 1.0, float(timed_out_ok), timed_out_ok),
    ]
    # write first: a red run leaves a diagnosable artifact
    write_bench(out, bench_envelope(
        "router_faults", gates=gates, ratio=goodput, t_start=t0,
        results=payload))

    # explicit raises, not asserts: CI gates, survive python -O
    if not all_ok:
        raise SystemExit(
            f"FAIL: chaos pass completed {results['chaos']['completed_ok']}"
            f" of {args.requests} requests ok (failover lost work)")
    if not streams_ok:
        raise SystemExit(
            "FAIL: chaos merged streams diverge from the healthy run "
            "(failover must replay from the prompt, bit-identical)")
    print("chaos merged streams identical to healthy run ✓")
    if not fired_once:
        raise SystemExit(
            f"FAIL: kill fault fired {faults_fired} times per repeat "
            f"(expected exactly once)")
    if not timed_out_ok:
        raise SystemExit(
            f"FAIL: deadline-expired request surfaced as "
            f"{deadline['statuses'].get(deadline['timed_out_uid'])!r} "
            f"(expected 'timed_out'; drained={deadline['drained']})")
    print("deadline-expired request surfaced timed_out, drain returned ✓")
    print(f"chaos / healthy goodput: {goodput:.2f}x "
          f"(floor {goodput_floor:.3f}; all paired: "
          f"{' '.join(f'{r:.2f}' for r in ratios)})")
    if goodput < goodput_floor:
        raise SystemExit(
            f"FAIL: chaos goodput must reach >= {goodput_floor:.3f}x "
            f"healthy (got {goodput:.2f}x)")


if __name__ == "__main__":
    main()
