"""Copy-on-write shared-prefix paging: page footprint + open-loop SLOs.

Two paged engines run the SAME overlapping-prefix traffic (a 112-token
shared system prompt ahead of a 16-token unique tail, greedy):

  * sharing-off — every admission copies its full prompt into private
    pages (the PR-3 baseline).
  * sharing-on  — admissions map full-page prompt prefixes onto the
    pages earlier requests already wrote (refcount bump, zero prefill
    recompute when the whole chain is resident); writes into a shared
    page copy-on-write.

The memory gate is the allocator's PEAK live page count over paired
interleaved repeats (identical same-seed traffic, peaks reset after
warmup): with 7 of 8 prompt pages shared, sharing must hold the peak
to <= 0.6x the unshared run (measured ~0.45x).  Streams must stay
bitwise identical in every repeat — sharing that drifts is a bug, not
a saving.

The serving gate drives the sharing engine OPEN-LOOP (workload.
run_open_loop): Poisson arrivals are submitted on the wall clock
whether or not capacity exists, so queueing delay lands in TTFT
exactly as a user would see it.  p95 TTFT and p95 TPOT must clear
smoke-model SLOs calibrated ~4x above the quiet-machine numbers —
loose enough for shared CI runners, tight enough to catch a sharing
hot path that recomputes prefills or serializes decode.

Emits BENCH_prefix_sharing.json; CI runs `--smoke` and fails on
stream divergence or a missed gate.

  PYTHONPATH=src python benchmarks/bench_prefix_sharing.py --smoke
"""
from __future__ import annotations

import argparse
import time

import jax

from common import bench_envelope, gate, write_bench

from repro import configs
from repro.models import api
from repro.serving.scheduler import ServingEngine
from repro.serving.workload import (latency_stats, poisson_arrivals,
                                    run_open_loop, shared_prefix_requests,
                                    warmup_engine)

PAGE_RATIO_GATE = 0.6
TTFT_P95_SLO_S = 2.0
TPOT_P95_SLO_S = 0.25


def _engine(cfg, params, dsg, args, sharing):
    return ServingEngine(cfg, params, dsg, n_slots=args.slots,
                         max_seq=args.max_seq, admission="overlap",
                         prompt_bucket=args.prompt_bucket,
                         cache_backend="paged", page_size=args.page_size,
                         cache_tokens=args.cache_tokens,
                         prefix_sharing=sharing)


def _traffic(cfg, args, *, seed=None):
    return shared_prefix_requests(
        cfg.vocab, args.requests, prompt_len=args.prompt_len,
        prefix_len=args.prefix_len, max_new=args.max_new,
        seed=args.seed if seed is None else seed)


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    while eng.queue or any(not s.free for s in eng.slots):
        eng.step()
        if eng.steps >= 100_000:    # explicit raise: survives python -O
            raise RuntimeError("engine failed to drain the workload")
    return {r.uid: list(r.output) for r in reqs}


def _measured_run(eng, cfg, args):
    """One steady-state repeat: fresh same-seed traffic, the allocator
    peak reset so it covers exactly this repeat (warmup requests have
    retired, so the index holds only what this repeat registers)."""
    reqs = _traffic(cfg, args)
    eng.steps = 0
    eng.backend.allocator.reset_peak()
    outputs = _drain(eng, reqs)
    be = eng.backend
    return outputs, {"peak_live_pages": be.allocator.peak_live,
                     "shared_page_hits": be.shared_page_hits,
                     "cow_copies": be.cow_copies,
                     "prefill_cache_hits": eng.prefill_cache_hits}


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    engines = {"off": _engine(cfg, params, dsg, args, False),
               "on": _engine(cfg, params, dsg, args, True)}
    for eng in engines.values():
        warmup_engine(eng, cfg.vocab, requests=_traffic(cfg, args))

    # -- closed-loop paired repeats: peak pages + stream equality -------
    repeats = {"off": [], "on": []}
    streams = {}
    streams_ok = True
    for _ in range(args.repeats):
        for mode, eng in engines.items():
            outputs, counters = _measured_run(eng, cfg, args)
            repeats[mode].append(counters)
            if mode == "off":
                streams = outputs
            elif outputs != streams:
                streams_ok = False
    ratios = [s["peak_live_pages"] / max(b["peak_live_pages"], 1)
              for b, s in zip(repeats["off"], repeats["on"])]
    page_ratio = min(ratios)     # pages are deterministic; min = best

    # -- open-loop Poisson drive on the sharing engine ------------------
    reqs = _traffic(cfg, args, seed=args.seed + 1)
    arrivals = poisson_arrivals(len(reqs), args.rate_rps, seed=args.seed)
    done = run_open_loop(engines["on"], reqs, arrivals)
    slo = latency_stats(done)

    return {"repeats": {f"sharing-{k}": v for k, v in repeats.items()},
            "paired_page_ratios": ratios,
            "page_ratio": page_ratio,
            "streams_ok": streams_ok,
            "open_loop": {"rate_rps": args.rate_rps,
                          "n_requests": len(reqs), **slo}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=192)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--cache-tokens", type=int, default=1024)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--prefix-len", type=int, default=112)
    ap.add_argument("--prompt-bucket", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rate-rps", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_prefix_sharing.json")
    args = ap.parse_args()

    t0 = time.time()
    results = run(args)
    print(f"{'repeat':>7} {'off peak pages':>15} {'on peak pages':>14} "
          f"{'ratio':>7}")
    off = results["repeats"]["sharing-off"]
    on = results["repeats"]["sharing-on"]
    for i, (b, s, r) in enumerate(zip(off, on,
                                      results["paired_page_ratios"])):
        print(f"{i:>7d} {b['peak_live_pages']:>15d} "
              f"{s['peak_live_pages']:>14d} {r:>7.2f}")
    print(f"sharing counters (last repeat): {on[-1]}")

    ratio = results["page_ratio"]
    streams_ok = results["streams_ok"]
    slo = results["open_loop"]
    ttft = slo.get("ttft_p95_s", float("inf"))
    tpot = slo.get("tpot_p95_s", float("inf"))
    print(f"best paired peak-page ratio = {ratio:.2f}x  "
          f"open-loop p95 TTFT = {ttft:.3f}s  p95 TPOT = {tpot:.4f}s")

    gates = [
        gate("sharing-on and sharing-off emit identical streams",
             1.0, float(streams_ok), streams_ok),
        gate(f"shared-prefix resident pages <= {PAGE_RATIO_GATE}x the "
             f"unshared run (best paired repeat)", PAGE_RATIO_GATE,
             ratio, ratio <= PAGE_RATIO_GATE),
        gate(f"open-loop p95 TTFT <= {TTFT_P95_SLO_S}s at "
             f"{slo['rate_rps']} rps", TTFT_P95_SLO_S, ttft,
             ttft <= TTFT_P95_SLO_S),
        gate(f"open-loop p95 TPOT <= {TPOT_P95_SLO_S}s at "
             f"{slo['rate_rps']} rps", TPOT_P95_SLO_S, tpot,
             tpot <= TPOT_P95_SLO_S),
    ]
    # write first: a red run leaves a diagnosable artifact
    write_bench(args.out, bench_envelope(
        "prefix_sharing", gates=gates, ratio=ratio, t_start=t0,
        results=results))

    # explicit raises, not asserts: CI regression gates, survive python -O
    if not streams_ok:
        raise SystemExit("FAIL: prefix sharing diverges from the "
                         "unshared streams")
    print("streams identical with sharing on vs off ✓")
    if ratio > PAGE_RATIO_GATE:
        raise SystemExit(
            f"FAIL: shared-prefix peak pages must be <= "
            f"{PAGE_RATIO_GATE}x the unshared run (got {ratio:.2f}x)")
    if ttft > TTFT_P95_SLO_S or tpot > TPOT_P95_SLO_S:
        raise SystemExit(
            f"FAIL: open-loop SLO missed (p95 TTFT {ttft:.3f}s vs "
            f"{TTFT_P95_SLO_S}s, p95 TPOT {tpot:.4f}s vs "
            f"{TPOT_P95_SLO_S}s)")


if __name__ == "__main__":
    main()
