"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run's compiled artifacts.

Hardware model (TPU v5e target):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link

Terms (seconds, per device = per step since SPMD is bulk-synchronous):
    compute    = HLO_FLOPs_dev / 197e12
    memory     = HLO_bytes_dev / 819e9
    collective = wire_bytes_dev / 50e9
      wire convention: all-gather / reduce-scatter / all-to-all /
      collective-permute send ~ their payload; all-reduce = 2x payload
      (ring AR = RS + AG).  Payloads come from the scan-aware HLO analyzer
      (launch/hlo_analysis.py), so collectives inside the layer loop are
      counted x trip_count.

MODEL_FLOPS (the "useful work" yardstick):
    train:  6 * N_active * tokens        (fwd 2x + bwd 4x)
    prefill: 2 * N_active * tokens
    decode: 2 * N_active * batch * 1 token (+ KV-cache reads counted in
            the memory term, not FLOPs — noted in EXPERIMENTS.md)
ratio = MODEL_FLOPS / (HLO_FLOPs_dev * devices): fraction of compiled
compute that is "useful"; < 1/3 for training means heavy remat/waste.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

AR_FACTOR = 2.0   # ring all-reduce = reduce-scatter + all-gather


def active_params(arch: str) -> tuple:
    """(total_params, active_params) from the abstract param tree."""
    from repro import configs
    from repro.models import api

    cfg = configs.get_config(arch)
    key = jax.random.PRNGKey(0)
    tree = jax.eval_shape(lambda: api.init_model(key, cfg))
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = active = 0
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if "moe" in keys and "shared" not in keys and "router" not in keys:
            # routed experts: only top_k of E are active per token
            active += n * cfg.moe_topk / cfg.moe_experts
        else:
            active += n
    return int(total), int(active)


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs.base import shape_by_name
    _, n_active = active_params(arch)
    sh = shape_by_name(shape_name)
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * sh.global_batch      # decode: 1 new token


def wire_bytes(analysis: dict, bf16_model: bool = True) -> float:
    """XLA:CPU float-normalization promotes bf16 compute (and therefore
    collective payloads) to f32 before SPMD partitioning; on the TPU
    target those collectives run at bf16.  For bf16 models we count f32
    payloads at half size (the logits/optimizer truly-f32 collectives are
    <2% of traffic — the residual error is noted in EXPERIMENTS.md)."""
    c = analysis["collectives"]

    def adj(kind):
        b = c[kind]["bytes"]
        f32 = c[kind].get("f32_bytes", 0.0)
        return b - 0.5 * f32 if bf16_model else b

    return (AR_FACTOR * adj("all-reduce")
            + adj("all-gather")
            + adj("reduce-scatter")
            + adj("all-to-all")
            + adj("collective-permute"))


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_dev: float
    useful_ratio: float
    temp_gb: float
    tag: str = "dsg"

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """No-overlap upper bound on the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound step time (the score)."""
        useful_s = self.model_flops / self.devices / PEAK_FLOPS
        return useful_s / max(self.step_s, 1e-12)


_MF_CACHE: dict = {}


def load_cell(path: str):
    rec = json.load(open(path))
    if rec.get("status") != "ok":
        return rec
    a = rec["analysis"]
    key = (rec["arch"], rec["shape"])
    if key not in _MF_CACHE:
        _MF_CACHE[key] = model_flops(*key)
    mf = _MF_CACHE[key]
    return Cell(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        devices=rec["devices"],
        compute_s=a["flops"] / PEAK_FLOPS,
        memory_s=a["bytes"] / HBM_BW,
        collective_s=wire_bytes(a) / LINK_BW,
        model_flops=mf,
        hlo_flops_dev=a["flops"],
        useful_ratio=mf / max(a["flops"] * rec["devices"], 1.0),
        temp_gb=rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        tag=rec.get("tag") or ("dsg" if rec.get("dsg", True) else "dense"),
    )


def load_all(results_dir: str = "results"):
    cells, skips = [], []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        c = load_cell(f)
        if isinstance(c, Cell):
            cells.append(c)
        else:
            skips.append(c)
    return cells, skips


def table(cells, mesh="single_pod") -> str:
    rows = [c for c in cells if c.mesh == mesh and c.tag == "dsg"]
    rows.sort(key=lambda c: (c.arch, c.shape))
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | step bound s | useful ratio | roofline frac | temp GB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for c in rows:
        out.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.4f} | {c.memory_s:.4f} "
            f"| {c.collective_s:.4f} | **{c.dominant}** | {c.step_s:.4f} "
            f"| {c.useful_ratio:.3f} | {c.roofline_fraction:.3f} "
            f"| {c.temp_gb:.1f} |")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--mesh", default="single_pod")
    args = ap.parse_args()
    cells, skips = load_all(args.results)
    print(table(cells, args.mesh))
    print(f"\ncells={len(cells)} skips={len(skips)}")
    # the three hillclimb candidates
    rows = [c for c in cells if c.mesh == args.mesh]
    if rows:
        worst = min(rows, key=lambda c: c.roofline_fraction)
        coll = max(rows, key=lambda c: c.collective_s / max(c.step_s, 1e-12))
        print(f"\nworst roofline fraction: {worst.arch} x {worst.shape} "
              f"({worst.roofline_fraction:.3f})")
        print(f"most collective-bound:   {coll.arch} x {coll.shape} "
              f"({coll.collective_s:.4f}s of {coll.step_s:.4f}s)")


if __name__ == "__main__":
    main()
