"""Serving-side DSG sparsity runtime: decode throughput + modeled FFN FLOPs.

Engines run the SAME mixed traffic through the serving DSG runtime
(serving/dsg_runtime.py) with different group-CSR FFN executors
(ModelConfig.dsg_ffn_apply):

  * dense      — masked-dense reference: full FFN matmuls, pattern applied
                 as an expanded mask (core/dsg_linear.swiglu_csr_masked).
                 Spends every FLOP the non-serving stack would; its
                 streams define bitwise-correct.
  * csr-xla    — bounded XLA gather: contracts only the leading
                 active-group bucket of each lane's CSR row.
  * csr-kernel — Pallas CSR walk (kernels/dsg_ffn.dsg_ffn_csr; interpret
                 mode off-TPU, so its latency column is only meaningful
                 on TPU — included for the stream gate).

threshold_mode="topk" keeps lanes computationally independent, so all
executors must agree token-for-token at temperature=0.  Three gates
(explicit raises, survive python -O):

  1. csr-xla (and csr-kernel when run) streams == dense reference, bitwise.
  2. Modeled FFN FLOP reduction (per-lane CSR counts vs dense groups,
     DSGRuntime.record_step) >= --flop-gate; 1.8x at the default
     gamma=0.5 (ideal 2.0x minus refresh/seeding slack).
  3. csr-xla measured decode tok/s >= --tps-gate x the dense reference
     (best paired repeat, interleaved runs) — sparsity must not tax the
     decode hot path.

Emits BENCH_dsg_serving.json in the shared benchmarks/common.py envelope;
CI runs `--smoke` and uploads the artifact.

  PYTHONPATH=src python benchmarks/bench_dsg_serving.py --smoke
"""
from __future__ import annotations

import argparse
import os

import jax

from common import bench_envelope, gate, write_bench

from repro import configs
from repro.models import api
from repro.serving.dsg_runtime import DSGServingConfig
from repro.serving.scheduler import ServingEngine
from repro.serving.workload import mixed_requests, warmup_engine


def _make_engine(cfg, params, dsg, args, apply_mode):
    vcfg = cfg.replace(dsg_ffn_apply=apply_mode)
    eng = ServingEngine(
        vcfg, params, dsg, n_slots=args.slots, max_seq=args.max_seq,
        prompt_bucket=args.prompt_bucket, admission="overlap",
        cache_backend=args.cache_backend, page_size=args.page_size,
        dsg_serving=DSGServingConfig(
            refresh_interval=args.refresh_interval,
            threshold=args.threshold))
    warmup_engine(eng, cfg.vocab)
    eng.dsg_rt.step_log.clear()      # FLOP model: measured window only
    return eng


def _drive(eng, cfg, args):
    """One measured pass of the traffic; returns (streams, decode tok/s)
    from the counter deltas so a warmed engine can be re-driven."""
    toks0, secs0 = eng.decode_tokens, eng.decode_seconds
    reqs = mixed_requests(cfg.vocab, args.requests, seed=args.seed,
                          prompt_range=(args.prompt_min, args.prompt_max),
                          max_new_range=(args.gen_min, args.gen_max))
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_steps=100_000)
    if len(done) < len(reqs):
        raise RuntimeError(
            f"engine drained only {len(done)}/{len(reqs)} requests")
    eng.done.clear()
    streams = {r.uid: list(r.output) for r in reqs}
    rate = ((eng.decode_tokens - toks0)
            / max(eng.decode_seconds - secs0, 1e-9))
    return streams, rate


def run(args) -> tuple:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    # topk: per-lane selection, lanes independent -> bitwise stream gate
    cfg = cfg.replace(dsg=cfg.dsg._replace(gamma=args.gamma,
                                           threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    # the Pallas walk needs a TPU (or the interpreter, REPRO_INTERPRET=1
    # — stream gate only; interpret latency means nothing)
    run_kernel = (jax.default_backend() == "tpu"
                  or bool(os.environ.get("REPRO_INTERPRET")))
    engines = {"dense": _make_engine(cfg, params, dsg, args, "dense"),
               "csr-xla": _make_engine(cfg, params, dsg, args, "xla")}
    if run_kernel:
        engines["csr-kernel"] = _make_engine(cfg, params, dsg, args,
                                             "kernel")

    # interleaved repeats: dense/sparse pairs share any machine-load
    # drift, the gate takes the best paired ratio (bench_router idiom)
    streams, rates = {}, {name: [] for name in engines}
    for rep in range(args.repeats):
        for name, eng in engines.items():
            if name == "csr-kernel" and rep > 0:
                continue             # stream gate only: one pass suffices
            s, rate = _drive(eng, cfg, args)
            prev = streams.setdefault(name, s)
            if prev != s:
                raise SystemExit(
                    f"FAIL: {name} streams differ across repeats "
                    f"(engine state leaking between runs)")
            rates[name].append(rate)

    results = {name: {"decode_tok_per_s": rates[name],
                      "steps": eng.steps,
                      "requests": args.repeats * args.requests}
               for name, eng in engines.items()}
    results["flop_model"] = engines["csr-xla"].dsg_rt.flop_stats()
    results["config"] = {
        "arch": args.arch, "gamma": args.gamma,
        "threshold": args.threshold,
        "refresh_interval": args.refresh_interval,
        "slots": args.slots, "requests": args.requests,
        "max_seq": args.max_seq, "prompt_bucket": args.prompt_bucket,
        "cache_backend": args.cache_backend, "repeats": args.repeats,
        "backend": jax.default_backend(), "kernel_ran": run_kernel}
    return streams, rates, results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--gamma", type=float, default=0.5,
                    help="fraction of neuron groups dropped; the default "
                         "FLOP gate (1.8x) assumes 0.5")
    ap.add_argument("--threshold", choices=("topk", "ema"),
                    default="topk")
    ap.add_argument("--refresh-interval", type=int, default=8)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--prompt-bucket", type=int, default=32)
    ap.add_argument("--prompt-min", type=int, default=4)
    ap.add_argument("--prompt-max", type=int, default=30)
    ap.add_argument("--gen-min", type=int, default=8)
    ap.add_argument("--gen-max", type=int, default=24)
    ap.add_argument("--cache-backend", choices=("dense", "paged"),
                    default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--flop-gate", type=float, default=1.8,
                    help="minimum modeled FFN FLOP reduction (csr model)")
    ap.add_argument("--tps-gate", type=float, default=0.95,
                    help="minimum csr-xla/dense best-paired decode tok/s")
    ap.add_argument("--out", default="BENCH_dsg_serving.json")
    args = ap.parse_args()

    import time
    t0 = time.time()
    streams, rates, results = run(args)

    print(f"{'executor':>11} {'decode tok/s (per repeat)':>34}")
    for name, rs in rates.items():
        print(f"{name:>11} {' '.join(f'{r:>10.1f}' for r in rs):>34}")
    flop = results["flop_model"]
    print(f"modeled FFN FLOP reduction: csr "
          f"{flop['flop_reduction_csr']:.2f}x, bound "
          f"{flop['flop_reduction_bound']:.2f}x over {flop['steps']} "
          f"steps (pattern overhead {flop['overhead_bytes']} bytes)")

    sparse_names = [n for n in streams if n != "dense"]
    streams_ok = all(streams[n] == streams["dense"] for n in sparse_names)
    paired = [s / d for s, d in zip(rates["csr-xla"], rates["dense"])]
    tps_ratio = max(paired)
    flop_red = flop["flop_reduction_csr"]
    gates = [
        gate("sparse executors match the dense-apply reference streams "
             "bitwise at temperature=0", 1.0, float(streams_ok),
             streams_ok),
        gate(f"modeled FFN FLOP reduction (csr) >= {args.flop_gate}x at "
             f"gamma={args.gamma}", args.flop_gate, flop_red,
             flop_red >= args.flop_gate),
        gate(f"csr-xla decode tok/s >= {args.tps_gate}x dense-apply "
             f"(best paired repeat)", args.tps_gate, tps_ratio,
             tps_ratio >= args.tps_gate),
    ]
    # write first: a red run must leave a diagnosable artifact (the
    # failed gate is recorded with passed=false)
    write_bench(args.out, bench_envelope(
        "dsg_serving", gates=gates, ratio=flop_red, t_start=t0,
        results=results))

    # explicit raises, not asserts: CI gates, survive python -O
    if not streams_ok:
        bad = [n for n in sparse_names if streams[n] != streams["dense"]]
        raise SystemExit(
            f"FAIL: {', '.join(bad)} diverge from the dense-apply "
            f"reference streams (group-CSR executor equivalence broken)")
    print("streams identical across FFN executors ✓")
    if flop_red < args.flop_gate:
        raise SystemExit(
            f"FAIL: modeled FFN FLOP reduction must reach >= "
            f"{args.flop_gate}x at gamma={args.gamma} "
            f"(got {flop_red:.2f}x)")
    print(f"csr-xla / dense decode throughput: {tps_ratio:.2f}x "
          f"(best paired repeat; all: "
          f"{' '.join(f'{r:.2f}' for r in paired)})")
    if tps_ratio < args.tps_gate:
        raise SystemExit(
            f"FAIL: csr-xla decode tok/s must stay >= {args.tps_gate}x "
            f"the dense-apply reference (got {tps_ratio:.2f}x)")


if __name__ == "__main__":
    main()
