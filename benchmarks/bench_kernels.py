"""Kernel-level benchmark: Pallas DSG FFN vs oracle — parity + the
block-skip accounting (fraction of (token-tile x group-block) MXU tiles
skipped vs gamma, i.e. the kernel-realized compute reduction)."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drs
from repro.kernels import ops, ref

GAMMAS = (0.3, 0.5, 0.7, 0.9)


def run(m=256, d=256, f=1024, block=64, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (m, d))
    wg = jax.random.normal(ks[1], (d, f)) / np.sqrt(d)
    wu = jax.random.normal(ks[2], (d, f)) / np.sqrt(d)
    wd = jax.random.normal(ks[3], (f, d)) / np.sqrt(f)
    r = jax.random.normal(ks[4], (128, d)) / np.sqrt(128)
    fw = r @ wg
    out = {"gammas": list(GAMMAS), "tile_skip_pertoken": [],
           "tile_skip_shared": [], "max_err": []}
    for g in GAMMAS:
        cfg = drs.DRSConfig(gamma=g, block=block)
        fx = ops.drs_project(x, r)
        scores = ops.drs_scores(fx, fw, block=block)
        mask, _ = drs.select_mask(scores, f, cfg)
        y = ops.dsg_ffn_fwd(x, wg, wu, wd, mask, block=block, bm=64, bf=64)
        yref = ref.dsg_ffn_ref(x, wg, wu, wd, mask, block)
        out["max_err"].append(float(jnp.abs(y - yref).max()))
        mt, ft = m // 64, f // 64

        def skip_frac(msk):
            tile = msk.reshape(mt, 64, ft, 64 // block).max(axis=(1, 3))
            return round(1.0 - float(tile.mean()), 4)

        # (a) uncorrelated per-token masks: tile = OR over 64 tokens ->
        #     little to skip (the paper's Fig 8(a) GEMM-hardness, measured)
        out["tile_skip_pertoken"].append(skip_frac(mask))
        # (b) batch-shared selection (gather_shared / converged masks):
        #     every tile agrees -> skip fraction == gamma
        shared = jnp.broadcast_to(mask[:1], mask.shape)
        out["tile_skip_shared"].append(skip_frac(shared))
    return out


def main():
    out = run()
    print("== Pallas DSG-FFN kernel: block-skip realization ==")
    print(f"{'gamma':>7} | {'skip(per-token)':>16} | {'skip(shared)':>13} "
          f"| {'max |err|':>10}")
    for g, a, b, e in zip(out["gammas"], out["tile_skip_pertoken"],
                          out["tile_skip_shared"], out["max_err"]):
        print(f"{g:7.2f} | {a:16.1%} | {b:13.1%} | {e:10.2e}")
    print("(per-token masks on random inputs barely skip whole tiles — the"
          " paper's Fig 8(a) GEMM finding, quantified; shared/converged"
          " selection skips exactly gamma of the MXU tiles)")
    json.dump(out, open("bench_results/kernels.json", "w"), indent=1)


if __name__ == "__main__":
    import os
    os.makedirs("bench_results", exist_ok=True)
    main()
