"""Paper Fig. 5(d), Table 1, Fig. 10(c): the epsilon knob.

JLL constant c=8 here (matches the paper's Table-1 dims); the framework
default is c=4 with a 128-lane floor (MXU alignment) — conservative.

For each epsilon: the JLL projection dim k, the dimension-reduction ratio,
the DRS op cost vs the full VMM (Table 1's 'Operations' columns, computed
for the paper's VGG8 layer shapes AND our assigned-arch FFN shapes), and
the empirical inner-product error distribution (Fig. 10(c))."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projection

EPS = (0.3, 0.5, 0.7, 0.9)
# paper Table 1 layers: (n_PQ rows, n_CRS dim, n_K outputs)
PAPER_LAYERS = ((1024, 1152, 128), (256, 1152, 256), (256, 2304, 256),
                (64, 2304, 512), (64, 4608, 512))
# our FFN analogues: (tokens/step/dev, d_model, d_ff)
ARCH_LAYERS = (("mistral-nemo-12b", 4096, 5120, 14336),
               ("llava-next-34b", 4096, 7168, 20480),
               ("internlm2-1.8b", 4096, 2048, 8192))


def run(seed=0):
    out = {"eps": list(EPS), "paper_table1": [], "arch_table": [],
           "inner_product": []}
    for rows, d, n_k in PAPER_LAYERS:
        entry = {"layer": f"{rows},{d},{n_k}", "dim": [], "mmacs": [],
                 "baseline_mmacs": rows * d * n_k / 1e6}
        for eps in EPS:
            k = projection.jll_dim(d, n_points=n_k + rows, eps=eps, c=8.0)
            entry["dim"].append(k)
            entry["mmacs"].append(round(rows * k * n_k / 1e6, 2))
        out["paper_table1"].append(entry)
    for name, rows, d, f in ARCH_LAYERS:
        entry = {"arch": name, "dim": [],
                 "search_frac": []}   # DRS cost / full VMM cost
        for eps in EPS:
            k = projection.jll_dim(d, n_points=f + rows, eps=eps, c=8.0)
            entry["dim"].append(k)
            entry["search_frac"].append(round(k / d, 4))
        out["arch_table"].append(entry)
    # Fig 10(c): inner-product error distribution at eps=0.5
    key = jax.random.PRNGKey(seed)
    d, n = 2048, 256
    x = jax.random.normal(key, (n, d)) / np.sqrt(d)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, n)) / np.sqrt(d)
    for eps in EPS:
        k = projection.jll_dim(d, 2 * n, eps, c=8.0)
        r = projection.make_projection(jax.random.fold_in(key, 2), k, d)
        err = (projection.project_rows(r, x) @ projection.project(r, w)
               - x @ w)
        out["inner_product"].append(
            {"eps": eps, "k": k,
             "err_std": float(jnp.std(err)),
             "err_p99": float(jnp.percentile(jnp.abs(err), 99))})
    return out


def main():
    out = run()
    print("== Table 1: dimension-reduction search cost ==")
    print(f"{'layer (nPQ,nCRS,nK)':>22} | {'BL dim':>7} | "
          + " | ".join(f"k@{e}" for e in EPS))
    for e in out["paper_table1"]:
        rows, d, nk = e["layer"].split(",")
        print(f"{e['layer']:>22} | {d:>7} | "
              + " | ".join(f"{k:4d}" for k in e["dim"])
              + f"   MMACs BL={e['baseline_mmacs']:.0f} -> "
              + "/".join(f"{m:.1f}" for m in e["mmacs"]))
    print("\n== assigned-arch DRS cost fraction (k/d) ==")
    for e in out["arch_table"]:
        print(f"{e['arch']:>22} | " + " | ".join(
            f"k={k} ({fr:.3f})" for k, fr in zip(e["dim"],
                                                 e["search_frac"])))
    print("\n== Fig 10(c): inner-product error (unit-norm rows) ==")
    for e in out["inner_product"]:
        print(f"eps={e['eps']}: k={e['k']} err_std={e['err_std']:.4f} "
              f"p99|err|={e['err_p99']:.4f}")
    json.dump(out, open("bench_results/epsilon.json", "w"), indent=1)
    return out


if __name__ == "__main__":
    import os
    os.makedirs("bench_results", exist_ok=True)
    main()
