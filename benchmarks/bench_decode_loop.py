"""Fused decode loop: device-resident chunked decode vs per-token dispatch.

Two engines run the SAME mixed traffic (threshold_mode="topk", greedy):

  * chunk-1 — the historical loop: one jitted dispatch per token, the
    host syncing (device->host token copy + python bookkeeping) between
    every step.
  * chunk-N — the fused loop (scheduler.make_chunked_decode_fns): N
    micro-steps scanned inside ONE dispatch, per-lane EOS/budget
    freezing on device, host bookkeeping lagging a chunk behind.

On the dispatch-bound smoke model the per-token host sync dominates the
decode wall clock, which is exactly the pathology ISSUE 9 fixes — so the
gate is wall-clock decode throughput, measured as decode_tokens /
decode_seconds over paired interleaved repeats (chunk-1 then chunk-N,
counters reset between repeats, identical same-seed traffic).  The
headline ratio is the BEST paired repeat (noise on shared CI runners
only ever slows a run down), gated at >= 1.5x.  Streams must stay
bitwise identical in every repeat — a fused loop that drifts is a bug,
not a speedup.

Emits BENCH_decode_loop.json; CI runs `--smoke` and fails on stream
divergence or a missed throughput gate.

  PYTHONPATH=src python benchmarks/bench_decode_loop.py --smoke
"""
from __future__ import annotations

import argparse
import time

import jax

from common import bench_envelope, gate, write_bench

from repro import configs
from repro.models import api
from repro.serving.scheduler import ServingEngine
from repro.serving.workload import mixed_requests, warmup_engine


def _engine(cfg, params, dsg, args, chunk):
    return ServingEngine(cfg, params, dsg, n_slots=args.slots,
                         max_seq=args.max_seq, admission="overlap",
                         prompt_bucket=args.prompt_bucket,
                         decode_chunk=chunk)


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    while eng.queue or any(not s.free for s in eng.slots):
        eng.step()
        if eng.steps >= 100_000:    # explicit raise: survives python -O
            raise RuntimeError("engine failed to drain the workload")
    return {r.uid: list(r.output) for r in reqs}


def _measured_run(eng, cfg, args):
    """One steady-state repeat: fresh same-seed traffic, counters reset
    so decode_tokens/decode_seconds cover exactly this repeat."""
    reqs = mixed_requests(
        cfg.vocab, args.requests, seed=args.seed,
        prompt_range=(args.prompt_min, args.prompt_max),
        max_new_range=(args.gen_min, args.gen_max))
    eng.steps = 0
    eng.decode_seconds = 0.0
    eng.decode_tokens = 0
    outputs = _drain(eng, reqs)
    tok_s = eng.decode_tokens / max(eng.decode_seconds, 1e-9)
    return outputs, tok_s, eng.decode_tokens, eng.decode_seconds


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    engines = {1: _engine(cfg, params, dsg, args, 1),
               args.chunk: _engine(cfg, params, dsg, args, args.chunk)}
    warm_reqs = mixed_requests(
        cfg.vocab, args.requests, seed=args.seed,
        prompt_range=(args.prompt_min, args.prompt_max),
        max_new_range=(args.gen_min, args.gen_max))
    for eng in engines.values():
        warmup_engine(eng, cfg.vocab, requests=warm_reqs)

    repeats = {1: [], args.chunk: []}
    streams = {}
    streams_ok = True
    # paired + interleaved: each repeat measures both loops back to back
    # so ambient runner noise hits them the same way
    for _ in range(args.repeats):
        for chunk, eng in engines.items():
            outputs, tok_s, toks, secs = _measured_run(eng, cfg, args)
            repeats[chunk].append(
                {"decode_tok_per_s": tok_s, "decode_tokens": toks,
                 "decode_seconds": secs})
            if chunk == 1:
                streams = outputs
            elif outputs != streams:
                streams_ok = False
    ratios = [f["decode_tok_per_s"] / b["decode_tok_per_s"]
              for b, f in zip(repeats[1], repeats[args.chunk])]
    return {"chunk": args.chunk,
            "repeats": {f"chunk-{k}": v for k, v in repeats.items()},
            "paired_ratios": ratios,
            "best_ratio": max(ratios),
            "streams_ok": streams_ok}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=32)
    ap.add_argument("--prompt-bucket", type=int, default=32)
    ap.add_argument("--gen-min", type=int, default=16)
    ap.add_argument("--gen-max", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_decode_loop.json")
    args = ap.parse_args()

    t0 = time.time()
    results = run(args)
    print(f"{'repeat':>7} {'chunk-1 tok/s':>14} "
          f"{'chunk-%d tok/s' % args.chunk:>14} {'ratio':>7}")
    base = results["repeats"]["chunk-1"]
    fused = results["repeats"][f"chunk-{args.chunk}"]
    for i, (b, f, r) in enumerate(zip(base, fused,
                                      results["paired_ratios"])):
        print(f"{i:>7d} {b['decode_tok_per_s']:>14.1f} "
              f"{f['decode_tok_per_s']:>14.1f} {r:>7.2f}")

    ratio = results["best_ratio"]
    streams_ok = results["streams_ok"]
    print(f"best paired decode throughput ratio = {ratio:.2f}x")

    gates = [
        gate("fused and per-token decode loops emit identical streams",
             1.0, float(streams_ok), streams_ok),
        gate(f"fused chunk={args.chunk} decode throughput >= 1.5x the "
             f"per-token loop (best paired repeat)", 1.5, ratio,
             ratio >= 1.5),
    ]
    # write first: a red run leaves a diagnosable artifact
    write_bench(args.out, bench_envelope(
        "decode_loop", gates=gates, ratio=ratio, t_start=t0,
        results=results))

    # explicit raises, not asserts: CI regression gates, survive python -O
    if not streams_ok:
        raise SystemExit("FAIL: fused decode loop diverges from the "
                         "per-token loop")
    print("streams identical across chunk sizes ✓")
    if ratio < 1.5:
        raise SystemExit(
            f"FAIL: fused decode loop must reach >= 1.5x the per-token "
            f"loop's decode throughput (got {ratio:.2f}x)")


if __name__ == "__main__":
    main()
