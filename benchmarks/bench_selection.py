"""Paper Fig. 5(c): graph-selection strategy — DRS vs oracle top-k vs
random, accuracy over the sparsity sweep.  Also covers Fig. 5(a)'s
sparsity-accuracy claim (<60% near-lossless, abrupt drop at high gamma)."""
import json

import jax

from benchmarks.common import make_cluster_data, train_mlp

GAMMAS = (0.0, 0.3, 0.5, 0.7, 0.875)
STRATS = ("drs", "oracle", "random")


def run(steps=300, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_cluster_data(jax.random.fold_in(key, 9))
    out = {"gammas": list(GAMMAS)}
    base, _ = train_mlp(key, data, strategy="none", gamma=0.0, steps=steps)
    out["dense"] = base
    for strat in STRATS:
        accs = []
        for g in GAMMAS:
            acc, _ = train_mlp(key, data, strategy=strat, gamma=g,
                               steps=steps)
            accs.append(round(acc, 4))
        out[strat] = accs
    return out


def main():
    out = run()
    print("== Fig 5(c): selection strategy (test accuracy) ==")
    print(f"dense baseline: {out['dense']:.4f}")
    print(f"{'gamma':>8} | " + " | ".join(f"{s:>8}" for s in STRATS))
    for i, g in enumerate(out["gammas"]):
        print(f"{g:8.3f} | " + " | ".join(
            f"{out[s][i]:8.4f}" for s in STRATS))
    # paper claims: DRS ~ oracle >> random at high sparsity
    hi = -1
    drs_o = out["drs"][hi] - out["oracle"][hi]
    drs_r = out["drs"][hi] - out["random"][hi]
    print(f"\nat gamma={out['gammas'][hi]}: drs-oracle={drs_o:+.4f} "
          f"drs-random={drs_r:+.4f}  "
          f"(claim: |drs-oracle| small, drs >> random)")
    json.dump(out, open("bench_results/selection.json", "w"), indent=1)
    return out


if __name__ == "__main__":
    import os
    os.makedirs("bench_results", exist_ok=True)
    main()
