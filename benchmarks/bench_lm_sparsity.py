"""Fig. 5(a) analogue on a transformer LM: training-loss vs DSG sparsity
on the internlm2 smoke config (synthetic stream)."""
import json

from repro import configs
from repro.launch.train import train

GAMMAS = (0.0, 0.3, 0.5, 0.75)


def run(steps=60, batch=8, seq=64):
    out = {"gammas": list(GAMMAS), "final_loss": []}
    for g in GAMMAS:
        cfg = configs.get_smoke_config("internlm2-1.8b")
        if g == 0.0:
            cfg = cfg.replace(dsg=cfg.dsg._replace(enabled=False))
        else:
            cfg = cfg.replace(dsg=cfg.dsg._replace(gamma=g))
        _, hist, _ = train(cfg, steps=steps, global_batch=batch, seq_len=seq)
        losses = [h["loss"] for h in hist]
        out["final_loss"].append(round(sum(losses[-10:]) / 10, 4))
    return out


def main():
    out = run()
    print("== Fig 5(a) analogue: LM loss vs DSG sparsity ==")
    for g, l in zip(out["gammas"], out["final_loss"]):
        print(f"  gamma={g:5.2f}  final_loss={l:.4f}")
    d0 = out["final_loss"][0]
    print(f"(claim shape: moderate sparsity ~ dense ({d0:.3f}); "
          "degradation grows with gamma)")
    json.dump(out, open("bench_results/lm_sparsity.json", "w"), indent=1)


if __name__ == "__main__":
    import os
    os.makedirs("bench_results", exist_ok=True)
    main()
