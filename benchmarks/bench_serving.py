"""Serving benchmark: wave vs overlap admission on mixed-length traffic.

The wave baseline admits only when every lane has drained (the seed
engine's policy); overlap admission splices each new prompt's KV pages into
any freed lane while the other lanes keep decoding.  On mixed-length
traffic (prompts 8-192, generation budgets 8-64, n_slots=4) the wave engine
strands lanes behind the longest request of each wave, so overlap wins on
both throughput and tail latency.

  PYTHONPATH=src python benchmarks/bench_serving.py --requests 48
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.models import api
from repro.serving.workload import mixed_requests, run_workload


def run(args) -> dict:
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    results = {}
    for admission in ("wave", "overlap"):
        best = None
        for _ in range(args.repeats):
            # identical traffic for both policies (fresh Request objects)
            reqs = mixed_requests(
                cfg.vocab, args.requests, seed=args.seed,
                prompt_range=(args.prompt_min, args.prompt_max),
                max_new_range=(args.gen_min, args.gen_max))
            st = run_workload(
                cfg, params, dsg, reqs, admission=admission,
                n_slots=args.slots, max_seq=args.max_seq,
                prompt_bucket=args.prompt_bucket)
            if best is None or st["tok_per_s"] > best["tok_per_s"]:
                best = st      # best-of-N: washes out host timing noise
        results[admission] = best
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full-size config (needs accelerators)")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=192)
    ap.add_argument("--gen-min", type=int, default=8)
    ap.add_argument("--gen-max", type=int, default=64)
    ap.add_argument("--max-seq", type=int, default=384)
    ap.add_argument("--prompt-bucket", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    results = run(args)
    print(f"{'policy':>8} {'tok/s':>9} {'p50 s':>7} {'p95 s':>7} "
          f"{'steps':>6} {'tokens':>7}")
    for name, st in results.items():
        print(f"{name:>8} {st['tok_per_s']:>9.1f} {st['p50_s']:>7.2f} "
              f"{st['p95_s']:>7.2f} {st['steps']:>6d} {st['tokens']:>7d}")
    speedup = results["overlap"]["tok_per_s"] / results["wave"]["tok_per_s"]
    print(f"overlap / wave throughput: {speedup:.2f}x")
    assert results["overlap"]["tokens"] == results["wave"]["tokens"], \
        "policies must generate identical token counts on identical traffic"


if __name__ == "__main__":
    main()
