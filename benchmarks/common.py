"""Shared harness for the paper-reproduction benchmarks: a small MLP
classifier (the paper's MLP/FASHION analogue — no datasets ship offline,
so a deterministic Gaussian-cluster task stands in) and a small LM, each
with pluggable DSG selection strategy (drs | oracle | random | none) —
plus the BENCH_*.json envelope every gated benchmark emits
(scripts/check_bench.py validates committed artifacts against it)."""
from __future__ import annotations

import datetime
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import double_mask, drs, masks, projection


# -- BENCH_*.json envelope ---------------------------------------------------
#
# Every gated benchmark writes the same top-level shape so dashboards and
# scripts/check_bench.py never special-case a file:
#
#   {"name":       "<benchmark id>",
#    "gates":      [{"description", "threshold", "value", "passed"}, ...],
#    "ratio":      <headline ratio the gates guard>,
#    "timestamps": {"start": <iso8601>, "end": <iso8601>},
#    "results":    {<benchmark-specific payload>}}
#
# Benchmark-specific numbers all live under "results"; the envelope keys
# are the stable cross-benchmark contract.

def gate(description: str, threshold: float, value: float,
         passed: bool) -> dict:
    """One CI gate entry: what was checked, against what, and the verdict
    (recorded even on failure so a red run leaves a diagnosable file)."""
    return {"description": description, "threshold": float(threshold),
            "value": float(value), "passed": bool(passed)}


def bench_envelope(name: str, *, gates: list, ratio: float,
                   t_start: float, results: dict) -> dict:
    """Wrap a benchmark's payload in the shared BENCH_*.json envelope.
    `t_start` is the time.time() captured before the measured runs; the
    end timestamp is stamped here."""
    now = datetime.datetime.now(datetime.timezone.utc)
    start = datetime.datetime.fromtimestamp(t_start,
                                            datetime.timezone.utc)
    return {"name": name,
            "gates": list(gates),
            "ratio": float(ratio),
            "timestamps": {"start": start.isoformat(),
                           "end": now.isoformat()},
            "results": results}


def write_bench(path: str, envelope: dict):
    with open(path, "w") as f:
        json.dump(envelope, f, indent=2)
        f.write("\n")
    print(f"wrote {path}")


def make_cluster_data(key, n_classes=16, dim=64, n_per_class=64,
                      noise=0.9, n_test_per_class=32):
    kc, ktr, kte = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_classes, dim)) * 2.0
    def draw(k, n):
        ks = jax.random.split(k, n_classes)
        xs = jnp.concatenate([
            centers[i] + noise * jax.random.normal(ks[i], (n, dim))
            for i in range(n_classes)])
        ys = jnp.repeat(jnp.arange(n_classes), n)
        return xs, ys
    xtr, ytr = draw(ktr, n_per_class)
    xte, yte = draw(kte, n_test_per_class)
    return (xtr, ytr), (xte, yte)


def init_mlp(key, dim=64, hidden=512, n_classes=16, depth=2):
    ks = jax.random.split(key, depth + 1)
    sizes = [dim] + [hidden] * depth + [n_classes]
    return {
        "w": [jax.random.normal(ks[i], (sizes[i], sizes[i + 1]))
              / np.sqrt(sizes[i]) for i in range(depth + 1)],
        "bn_scale": [jnp.ones(hidden) for _ in range(depth)],
        "bn_bias": [jnp.zeros(hidden) for _ in range(depth)],
    }


def mlp_forward(params, x, *, strategy="none", gamma=0.5, block=32,
                dsg_state=None, rng=None, use_bn=False, mask_mode="double"):
    """2-hidden-layer ReLU MLP with DSG selection on each hidden layer.

    strategy: none | drs | oracle | random (paper Fig. 5(c)).
    use_bn + mask_mode: the Fig. 5(e) double-mask study ('single'|'double').
    """
    h = x
    depth = len(params["w"]) - 1
    cfg = drs.DRSConfig(gamma=gamma, block=block, threshold_mode="topk")
    for i in range(depth):
        w = params["w"][i]
        pre = h @ w
        f = w.shape[1]
        if strategy == "none" or gamma == 0.0:
            gmask = None
        elif strategy == "oracle":
            gmask = drs.oracle_mask(pre, f, cfg)
        elif strategy == "random":
            rng, sub = jax.random.split(rng)
            gmask = drs.random_mask(sub, pre.shape[:-1], f, cfg)
        else:  # drs
            st = dsg_state[i]
            fx = projection.project_rows(st["r"], h)
            gmask, _ = drs.drs_mask(fx, st["fw"], cfg)
        act = jax.nn.relu(pre)
        if gmask is not None:
            gmask = masks.freeze(gmask)
            act = masks.apply_expanded(act, gmask, block)
        if use_bn:
            def bn(z, i=i):
                return double_mask.batch_norm_train(
                    z, params["bn_scale"][i], params["bn_bias"][i])
            if gmask is None:
                act = bn(act)
            elif mask_mode == "double":
                act = double_mask.double_mask(bn, act, gmask, block)
            else:
                act = double_mask.single_mask(bn, act, gmask, block)
        h = act
    return h @ params["w"][-1], rng


def make_dsg_state(key, params, eps=0.5):
    state = []
    for i, w in enumerate(params["w"][:-1]):
        d, f = w.shape
        k = projection.jll_dim(d, f, eps)
        r = projection.make_projection(jax.random.fold_in(key, i), k, d)
        state.append({"r": r, "fw": projection.project(r, w)})
    return state


def train_mlp(key, data, *, strategy="none", gamma=0.5, block=32,
              steps=300, lr=0.05, use_bn=False, mask_mode="double",
              eps=0.5, refresh_every=50):
    (xtr, ytr), (xte, yte) = data
    params = init_mlp(jax.random.fold_in(key, 0))
    dsg_state = make_dsg_state(jax.random.fold_in(key, 1), params, eps) \
        if strategy == "drs" else None
    rng = jax.random.fold_in(key, 2)

    def loss_fn(p, st, rng):
        logits, _ = mlp_forward(p, xtr, strategy=strategy, gamma=gamma,
                                block=block, dsg_state=st, rng=rng,
                                use_bn=use_bn, mask_mode=mask_mode)
        onehot = jax.nn.one_hot(ytr, logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for step in range(steps):
        rng, sub = jax.random.split(rng)
        loss, g = grad_fn(params, dsg_state, sub)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        if strategy == "drs" and (step + 1) % refresh_every == 0:
            for i, w in enumerate(params["w"][:-1]):
                dsg_state[i]["fw"] = projection.project(dsg_state[i]["r"], w)

    logits, _ = mlp_forward(params, xte, strategy=strategy, gamma=gamma,
                            block=block, dsg_state=dsg_state, rng=rng,
                            use_bn=use_bn, mask_mode=mask_mode)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == yte))
    return acc, float(loss)
