"""Benchmark orchestrator: one section per paper table/figure, plus the
roofline report from the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # skip training benches
"""
import argparse
import os
import time


def _section(title):
    print("\n" + "=" * 72)
    print(title)
    print("=" * 72, flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-based benchmarks")
    args = ap.parse_args()
    os.makedirs("bench_results", exist_ok=True)
    t0 = time.time()

    _section("Roofline (deliverable g) — baseline dry-run artifacts")
    from benchmarks import roofline
    if os.path.isdir("results"):
        cells, skips = roofline.load_all("results")
        print(roofline.table(cells, "single_pod"))
        print(f"[baseline] cells={len(cells)} skips={len(skips)}")
    if os.path.isdir("results_opt"):
        _section("Roofline — OPTIMIZED defaults (post-hillclimb)")
        cells, _ = roofline.load_all("results_opt")
        print(roofline.table(cells, "single_pod"))

    _section("Table 1 / Fig 5(d) / Fig 10(c): epsilon & DRS cost")
    from benchmarks import bench_epsilon
    bench_epsilon.main()

    _section("Fig 6: memory footprint (stash compression model)")
    from benchmarks import bench_memory
    bench_memory.main()

    _section("Fig 7: operation reduction")
    from benchmarks import bench_ops
    bench_ops.main()

    _section("Pallas kernel: block-skip realization + parity")
    from benchmarks import bench_kernels
    bench_kernels.main()

    if not args.fast:
        _section("Fig 5(c): selection strategy (DRS vs oracle vs random)")
        from benchmarks import bench_selection
        bench_selection.main()

        _section("Fig 5(e): double-mask BN compatibility")
        from benchmarks import bench_double_mask
        bench_double_mask.main()

        _section("Fig 11: mask convergence")
        from benchmarks import bench_mask_convergence
        bench_mask_convergence.main()

        _section("Fig 5(a) analogue: LM loss vs sparsity")
        from benchmarks import bench_lm_sparsity
        bench_lm_sparsity.main()

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s; "
          "JSON artifacts in bench_results/")


if __name__ == "__main__":
    main()
