"""Paper Fig. 5(e): BN compatibility — {no BN, BN+single mask,
BN+double mask} across sparsity."""
import json

import jax

from benchmarks.common import make_cluster_data, train_mlp

GAMMAS = (0.3, 0.5, 0.7, 0.875)


def run(steps=300, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_cluster_data(jax.random.fold_in(key, 9))
    out = {"gammas": list(GAMMAS), "no_bn": [], "bn_single": [],
           "bn_double": []}
    for g in GAMMAS:
        a, _ = train_mlp(key, data, strategy="drs", gamma=g, steps=steps,
                         use_bn=False)
        out["no_bn"].append(round(a, 4))
        a, _ = train_mlp(key, data, strategy="drs", gamma=g, steps=steps,
                         use_bn=True, mask_mode="single")
        out["bn_single"].append(round(a, 4))
        a, _ = train_mlp(key, data, strategy="drs", gamma=g, steps=steps,
                         use_bn=True, mask_mode="double")
        out["bn_double"].append(round(a, 4))
    return out


def main():
    out = run()
    print("== Fig 5(e): double-mask BN compatibility (test accuracy) ==")
    print(f"{'gamma':>8} | {'no_bn':>8} | {'bn+single':>9} | {'bn+double':>9}")
    for i, g in enumerate(out["gammas"]):
        print(f"{g:8.3f} | {out['no_bn'][i]:8.4f} | "
              f"{out['bn_single'][i]:9.4f} | {out['bn_double'][i]:9.4f}")
    json.dump(out, open("bench_results/double_mask.json", "w"), indent=1)
    return out


if __name__ == "__main__":
    import os
    os.makedirs("bench_results", exist_ok=True)
    main()
