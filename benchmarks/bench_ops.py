"""Paper Fig. 7 / §3.4: computational-cost reduction.

Analytic MAC accounting per assigned arch (forward + backward), mirroring
the paper's convention: DRS search cost is included as overhead; the
backward weight-gradient GEMM is NOT credited with sparsity savings
("practical concern" — same convention as the paper).  Where dry-run HLO
FLOPs for dense vs DSG variants exist (results/), the measured ratio is
reported alongside."""
import glob
import json
import os

from repro import configs
from repro.core import projection

GAMMAS = (0.5, 0.8, 0.9)


def ffn_macs(cfg, tokens):
    f = cfg.moe_d_ff * cfg.moe_topk if cfg.is_moe else max(cfg.d_ff, 1)
    return 3 * tokens * cfg.d_model * f      # gate+up+down


def arch_reduction(cfg, gamma, tokens=4096):
    d, dff = cfg.d_model, (cfg.moe_d_ff if cfg.is_moe else max(cfg.d_ff, 1))
    k = projection.jll_dim(d, dff, cfg.dsg.eps)
    dense_f = ffn_macs(cfg, tokens)
    # forward: gate/up columns + down rows of kept groups + DRS search
    fwd = dense_f * (1 - gamma) + tokens * k * dff / (3 if cfg.is_moe else 1)
    search = tokens * (k * d + k * dff)
    # backward: error-prop benefits (2/3 of bwd GEMMs), dW does not (1/3)
    dense_bwd = 2 * dense_f
    bwd = dense_bwd * (2 / 3) * (1 - gamma) + dense_bwd * (1 / 3)
    train_ratio = (dense_f + dense_bwd) / (fwd + search + bwd)
    infer_ratio = dense_f / (fwd + search)
    overhead = search / (fwd + search)
    return train_ratio, infer_ratio, overhead


def measured_ratios():
    """Measured HLO-FLOP ratios from dry-run JSONs: dense vs the
    paper-faithful mask mode (expected ~1.0: XLA cannot skip dynamic
    per-token columns — the kernel realizes that cut) and dense vs the
    shard_map gather mode (the XLA-visible (1-gamma) cut, §Perf A8)."""
    out = {}
    for f in glob.glob("results/*__dense.json"):
        a = json.load(open(f))
        if a.get("status") != "ok":
            continue
        key = f"{a['arch']}/{a['shape']}"
        rec = {}
        for tag, name in (("dsg", "dense/mask"),
                          ("A8_gather_shardmap", "dense/gather")):
            g = f.replace("__dense.json", f"__{tag}.json")
            if os.path.exists(g):
                b = json.load(open(g))
                if b.get("status") == "ok":
                    rec[name] = round(a["analysis"]["flops"]
                                      / b["analysis"]["flops"], 4)
        if rec:
            out[key] = rec
    return out


def main():
    print("== Fig 7: FFN operation reduction (analytic, per assigned arch) ==")
    print(f"{'arch':>22} | " + " | ".join(
        f"train@{g} / infer@{g} / DRS-ovh" for g in GAMMAS))
    rows = []
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        cells = []
        rec = {"arch": arch}
        for g in GAMMAS:
            tr, inf, ovh = arch_reduction(cfg, g)
            cells.append(f"{tr:4.2f}x/{inf:4.2f}x/{ovh:5.1%}")
            rec[f"train@{g}"] = round(tr, 3)
            rec[f"infer@{g}"] = round(inf, 3)
            rec[f"overhead@{g}"] = round(ovh, 4)
        rows.append(rec)
        print(f"{arch:>22} | " + " | ".join(cells))
    print("\npaper claims: train 1.4x/1.7x/2.2x, infer 1.5x/2.8x/3.9x at "
          "50/80/90%; DRS overhead <6.5% train, <19.5% infer")
    m = measured_ratios()
    if m:
        print("\nmeasured dense/dsg HLO-FLOP ratios (dry-run):")
        for k, v in m.items():
            print(f"  {k}: {v}")
    json.dump({"analytic": rows, "measured": m},
              open("bench_results/ops.json", "w"), indent=1)


if __name__ == "__main__":
    os.makedirs("bench_results", exist_ok=True)
    main()
