"""Paper Fig. 6: representational-cost (memory) reduction.

Training: params + all stashed activations; inference: params + largest
layer activation.  Computed with the compressed-stash model (core/stash.py)
for every assigned architecture at the paper's three sparsity levels, plus
the measured dry-run temp sizes where available."""
import json

import jax

from repro import configs
from repro.core import stash
from repro.models import api

GAMMAS = (0.5, 0.8, 0.9)


def act_shapes(cfg, batch, seq):
    """Per-layer stashed-activation shapes for one step (residual +
    FFN hidden per layer — the dominant stash terms)."""
    shapes = []
    f = cfg.moe_d_ff * cfg.moe_topk if cfg.is_moe else max(cfg.d_ff, 1)
    for _ in range(cfg.n_layers):
        shapes.append((batch, seq, cfg.d_model))       # residual stream
        shapes.append((batch, seq, f))                 # masked FFN hidden
    return shapes


def run(batch=8, seq=4096):
    out = []
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        import math
        n_params = sum(
            math.prod(l.shape)
            for l in jax.tree.leaves(jax.eval_shape(
                lambda: api.init_model(jax.random.PRNGKey(0), cfg))))
        pbytes = n_params * 2
        shapes = act_shapes(cfg, batch, seq)
        rec = {"arch": arch, "param_gb": round(pbytes / 1e9, 2)}
        for g in GAMMAS:
            tr = stash.training_footprint(shapes, g, cfg.dsg.block, pbytes)
            inf = stash.inference_footprint(shapes, g, cfg.dsg.block, pbytes)
            rec[f"train_ratio@{g}"] = round(tr["ratio_total"], 2)
            rec[f"train_act_ratio@{g}"] = round(tr["ratio_activations"], 2)
            rec[f"infer_ratio@{g}"] = round(inf["ratio_total"], 2)
        out.append(rec)
    return out


def main():
    out = run()
    print("== Fig 6: memory footprint reduction (batch=8/dev, seq=4096) ==")
    print(f"{'arch':>22} | {'params':>7} | "
          + " | ".join(f"train@{g}" for g in GAMMAS)
          + " | " + " | ".join(f"act@{g}" for g in GAMMAS)
          + " | " + " | ".join(f"inf@{g}" for g in GAMMAS))
    for r in out:
        print(f"{r['arch']:>22} | {r['param_gb']:6.1f}G | "
              + " | ".join(f"{r[f'train_ratio@{g}']:7.2f}x" for g in GAMMAS)
              + " | " + " | ".join(f"{r[f'train_act_ratio@{g}']:5.2f}x"
                                   for g in GAMMAS)
              + " | " + " | ".join(f"{r[f'infer_ratio@{g}']:5.2f}x"
                                   for g in GAMMAS))
    print("\npaper claims: train 1.7x@50% 3.2x@80% 4.2x@90% (overall), "
          "up to 7.1x activations-only; mask overhead <2%")
    json.dump(out, open("bench_results/memory.json", "w"), indent=1)
    return out


if __name__ == "__main__":
    import os
    os.makedirs("bench_results", exist_ok=True)
    main()
