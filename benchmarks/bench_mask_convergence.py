"""Paper Fig. 11 / Appendix C: selection-mask convergence.

(a) per-sample mask drift between adjacent training epochs -> converges;
(b) mask difference between adjacent samples after training -> stays large
(why the paper keeps the on-the-fly search at inference instead of caching
masks)."""
import json

import jax
import jax.numpy as jnp

from benchmarks.common import (init_mlp, make_cluster_data, make_dsg_state,
                               mlp_forward)
from repro.core import drs, projection


def run(steps=240, record_every=20, seed=0, gamma=0.5, block=32):
    key = jax.random.PRNGKey(seed)
    (xtr, ytr), _ = make_cluster_data(jax.random.fold_in(key, 9))
    probe = xtr[:64]
    params = init_mlp(jax.random.fold_in(key, 0))
    state = make_dsg_state(jax.random.fold_in(key, 1), params)
    cfg = drs.DRSConfig(gamma=gamma, block=block)

    def probe_mask(params, state):
        h = probe
        fx = projection.project_rows(state[0]["r"], h)
        mask, _ = drs.drs_mask(fx, state[0]["fw"], cfg)
        return mask

    def loss_fn(p, st):
        logits, _ = mlp_forward(p, xtr, strategy="drs", gamma=gamma,
                                block=block, dsg_state=st)
        onehot = jax.nn.one_hot(ytr, logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    grad_fn = jax.jit(jax.grad(loss_fn))
    drift, prev = [], probe_mask(params, state)
    for step in range(steps):
        g = grad_fn(params, state)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        if (step + 1) % 50 == 0:
            for i, w in enumerate(params["w"][:-1]):
                state[i]["fw"] = projection.project(state[i]["r"], w)
        if (step + 1) % record_every == 0:
            cur = probe_mask(params, state)
            drift.append(float(jnp.mean(jnp.abs(cur - prev))))
            prev = cur
    final = probe_mask(params, state)
    across = float(jnp.mean(jnp.abs(final[1:] - final[:-1])))
    return {"drift_per_interval": drift, "across_samples_after": across}


def main():
    out = run()
    print("== Fig 11: mask convergence ==")
    print("per-sample mask drift over training (L1/group, every 20 steps):")
    print("  " + " ".join(f"{d:.3f}" for d in out["drift_per_interval"]))
    print(f"across-sample mask difference after training: "
          f"{out['across_samples_after']:.3f}")
    print("(claim: drift -> small; across-sample difference stays large "
          "-> cache-all-masks would not pay, keep on-the-fly DRS)")
    json.dump(out, open("bench_results/mask_convergence.json", "w"), indent=1)


if __name__ == "__main__":
    import os
    os.makedirs("bench_results", exist_ok=True)
    main()
