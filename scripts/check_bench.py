#!/usr/bin/env python
"""Validate committed BENCH_*.json artifacts against the shared envelope.

Every gated benchmark (benchmarks/bench_paged_decode.py, bench_router.py,
bench_router_faults.py, bench_dsg_serving.py, bench_decode_loop.py,
bench_prefix_sharing.py) wraps its payload in the envelope from
benchmarks/common.py:

  {"name":       str,
   "gates":      [{"description": str, "threshold": num, "value": num,
                   "passed": bool}, ...],      # non-empty
   "ratio":      num,                          # the headline ratio
   "timestamps": {"start": iso8601, "end": iso8601},  # end >= start
   "results":    dict}                         # benchmark-specific

This script checks every committed BENCH_*.json parses, carries exactly
that shape, and has every gate passed — a committed artifact from a red
run (the benches write before raising, so failures leave diagnosable
files) must never land.  Extra top-level keys are rejected: they belong
under "results", where dashboards expect benchmark-specific payloads.

  python scripts/check_bench.py              # repo root artifacts
  python scripts/check_bench.py --root DIR   # testing

No dependencies beyond the standard library.
"""
from __future__ import annotations

import argparse
import datetime
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

TOP_KEYS = {"name", "gates", "ratio", "timestamps", "results"}
GATE_KEYS = {"description", "threshold", "value", "passed"}


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _iso(ts) -> datetime.datetime | None:
    try:
        t = datetime.datetime.fromisoformat(ts)
    except (TypeError, ValueError):
        return None
    if t.tzinfo is None:           # naive timestamps compare as UTC
        t = t.replace(tzinfo=datetime.timezone.utc)
    return t


def check_file(path: Path) -> list:
    """All envelope violations in one artifact (empty list = clean)."""
    try:
        data = json.loads(path.read_text(encoding="utf-8"))
    except (ValueError, UnicodeDecodeError) as e:
        return [f"not valid JSON: {e}"]
    if not isinstance(data, dict):
        return ["top level must be an object"]

    bad = []
    missing, extra = TOP_KEYS - set(data), set(data) - TOP_KEYS
    if missing:
        bad.append(f"missing keys: {sorted(missing)}")
    if extra:
        bad.append(f"unexpected top-level keys {sorted(extra)} "
                   f"(benchmark payloads belong under 'results')")

    if "name" in data and not (isinstance(data["name"], str)
                               and data["name"]):
        bad.append("'name' must be a non-empty string")
    if "ratio" in data and not _num(data["ratio"]):
        bad.append("'ratio' must be a number")
    if "results" in data and not isinstance(data["results"], dict):
        bad.append("'results' must be an object")

    gates = data.get("gates")
    if gates is not None:
        if not isinstance(gates, list) or not gates:
            bad.append("'gates' must be a non-empty list")
        else:
            for i, g in enumerate(gates):
                if not isinstance(g, dict) or set(g) != GATE_KEYS:
                    bad.append(f"gates[{i}] must have exactly "
                               f"{sorted(GATE_KEYS)}")
                    continue
                if not (isinstance(g["description"], str)
                        and g["description"]):
                    bad.append(f"gates[{i}].description must be a "
                               f"non-empty string")
                if not (_num(g["threshold"]) and _num(g["value"])):
                    bad.append(f"gates[{i}] threshold/value must be "
                               f"numbers")
                if not isinstance(g["passed"], bool):
                    bad.append(f"gates[{i}].passed must be a bool")
                elif not g["passed"]:
                    bad.append(f"gates[{i}] FAILED: "
                               f"{g.get('description')} "
                               f"(value {g.get('value')} vs threshold "
                               f"{g.get('threshold')}) — a red-run "
                               f"artifact must not be committed")

    ts = data.get("timestamps")
    if ts is not None:
        if not isinstance(ts, dict) or set(ts) != {"start", "end"}:
            bad.append("'timestamps' must be {'start', 'end'}")
        else:
            start, end = _iso(ts["start"]), _iso(ts["end"])
            if start is None or end is None:
                bad.append("timestamps must be ISO-8601 strings")
            elif end < start:
                bad.append(f"timestamps end < start "
                           f"({ts['end']} < {ts['start']})")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=str(REPO),
                    help="directory whose BENCH_*.json files to check "
                         "(default: repo root)")
    args = ap.parse_args()

    root = Path(args.root)
    files = sorted(root.glob("BENCH_*.json"))
    if not files:
        print(f"FAIL no BENCH_*.json found under {root} — gated "
              f"benchmarks commit their artifacts")
        sys.exit(1)

    failures = 0
    for path in files:
        problems = check_file(path)
        for p in problems:
            print(f"FAIL {path.name}: {p}")
        failures += len(problems)
    if failures:
        sys.exit(1)
    print(f"ok: {len(files)} BENCH_*.json artifacts match the shared "
          f"envelope, all gates green")


if __name__ == "__main__":
    main()
