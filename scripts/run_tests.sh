#!/usr/bin/env bash
# Tier-1 test entry point (local + CI).
#
#   scripts/run_tests.sh            # whole suite
#   scripts/run_tests.sh tests/test_serving.py -k eos   # pass-through args
#
# Forces the CPU platform with 8 virtual host devices so the multi-device
# shard_map/pipeline tests exercise real collectives; subprocess tests that
# need a different device count set their own XLA_FLAGS.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

if ! python -c "import hypothesis" >/dev/null 2>&1; then
  pip install -q -r requirements-dev.txt \
    || echo "warning: could not install requirements-dev.txt (offline?);" \
            "hypothesis-based modules will be skipped"
fi

exec python -m pytest -q "$@"
