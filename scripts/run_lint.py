#!/usr/bin/env python
"""repro-lint CLI: repo-specific static analysis with a baseline gate.

    PYTHONPATH=src python scripts/run_lint.py                 # lint src/
    PYTHONPATH=src python scripts/run_lint.py --fail-on-new   # CI gate
    PYTHONPATH=src python scripts/run_lint.py --write-baseline
    PYTHONPATH=src python scripts/run_lint.py --report lint_report.json

Checks (src/repro/analysis/, docs/analysis.md):

  jit_hygiene       JIT101-106  host syncs / tracer branching / closure
                                capture / non-hashable statics in traced code
  locks             LCK201-202  @locked_by/@owned_by field discipline
  pallas_contracts  PAL301-303  interpret-mode reads, grid/index_map purity
  pytrees           PYT401     dataclasses crossing jit must be pytrees

Baseline: scripts/lint_baseline.json holds ACCEPTED findings (each with
a mandatory reason).  `--fail-on-new` exits 1 on any finding not in the
baseline, on baseline entries with an empty reason, and on stale entries
(accepted findings that no longer fire — remove them).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.findings import load_baseline, write_baseline  # noqa: E402
from repro.analysis.runner import run_lint  # noqa: E402

DEFAULT_BASELINE = REPO / "scripts" / "lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src/)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="accepted-findings file (default: "
                         "scripts/lint_baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept every current finding into the baseline "
                         "(then fill in each entry's 'reason')")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 1 on findings outside the baseline, "
                         "unreasoned baseline entries, or stale entries")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write a JSON report (findings + baseline "
                         "partition) for CI artifacts")
    ap.add_argument("--root", default=str(REPO / "src"),
                    help="tree to analyze (default: src/; tests point "
                         "this at fixture corpora)")
    args = ap.parse_args(argv)

    # always index the whole root (findings depend on cross-module call
    # resolution); path arguments only filter what gets REPORTED
    root = Path(args.root).resolve()
    findings = run_lint(root)
    if args.paths:
        keep = set()
        for p in args.paths:
            path = Path(p).resolve()
            cands = ([f for f in path.rglob("*.py")] if path.is_dir()
                     else [path])
            for f in cands:
                try:
                    keep.add(str(f.relative_to(root)))
                except ValueError:
                    pass
        findings = [f for f in findings if f.file in keep]
        if not keep:
            print(f"run_lint: no analyzable files under src/ in "
                  f"{args.paths}", file=sys.stderr)
            return 2
    baseline = load_baseline(args.baseline)

    if args.write_baseline:
        write_baseline(args.baseline, findings, previous=baseline)
        print(f"wrote {len(findings)} accepted finding(s) to "
              f"{args.baseline}; fill in every empty 'reason'")
        return 0

    new, accepted = baseline.split(findings)
    stale = baseline.stale(findings)
    unreasoned = baseline.unreasoned()

    for f in new:
        print(f.render())
    if accepted:
        print(f"({len(accepted)} baselined finding(s) suppressed)")
    for fp in stale:
        print(f"stale baseline entry (violation fixed — remove it): {fp}")
    for fp in unreasoned:
        print(f"baseline entry without a reason: {fp}")

    if args.report:
        payload = {
            "root": str(root),
            "new": [f.as_dict() for f in new],
            "accepted": [f.as_dict() for f in accepted],
            "stale_baseline": stale,
            "unreasoned_baseline": unreasoned,
        }
        Path(args.report).write_text(json.dumps(payload, indent=2) + "\n",
                                     encoding="utf-8")

    if new:
        print(f"repro-lint: {len(new)} new finding(s)")
        return 1
    if args.fail_on_new and (stale or unreasoned):
        print("repro-lint: baseline needs attention "
              f"({len(stale)} stale, {len(unreasoned)} unreasoned)")
        return 1
    print(f"repro-lint: clean ({len(accepted)} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
