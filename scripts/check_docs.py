#!/usr/bin/env python
"""Docs checks for CI: intra-repo markdown links + runnable quickstart.

Two modes (both exit non-zero on failure):

  python scripts/check_docs.py                  # link check
  python scripts/check_docs.py --run-quickstart # run README's quickstart

**Link check.** Every `[text](target)` in every tracked markdown file
is resolved: `http(s)`/`mailto` targets are skipped, everything else
must exist relative to the file (directories allowed), and `#anchor`
fragments pointing into a markdown file must match a heading's
GitHub-style slug.  Code fences are stripped first so exemplar snippets
(SNIPPETS.md) cannot produce false positives.  No dependencies beyond
the standard library.

**Quickstart runner.** Extracts the first ```bash fence under the
`## Quickstart` heading in README.md and runs it with
`REPRO_INTERPRET=1` (Pallas kernels in interpret mode) so the
documented one-liner is executed, not just trusted, on every push.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — not preceded by '!' (images would also be fine, but
# keep the regex honest) and not a footnote/reference-style link
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```.*?```", re.S)
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def _md_files():
    for path in sorted(REPO.rglob("*.md")):
        if any(part.startswith(".") for part in path.relative_to(REPO).parts):
            continue
        yield path


def _slugify(heading: str) -> str:
    """GitHub's anchor slug: strip formatting, lowercase, keep word
    chars/hyphens, spaces to hyphens."""
    text = heading.strip().lower()
    text = text.replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(path: Path) -> set:
    text = _FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    return {_slugify(h) for h in _HEADING_RE.findall(text)}


def check_links() -> int:
    bad = []
    for path in _md_files():
        text = _FENCE_RE.sub("", path.read_text(encoding="utf-8"))
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel = path.relative_to(REPO)
            target, _, anchor = target.partition("#")
            dest = path if not target else (path.parent / target).resolve()
            if not dest.exists():
                bad.append(f"{rel}: dead link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if _slugify(anchor) not in _anchors(dest):
                    bad.append(f"{rel}: dead anchor -> "
                               f"{target or rel.name}#{anchor}")
    for line in bad:
        print(f"FAIL {line}")
    if not bad:
        n = len(list(_md_files()))
        print(f"ok: intra-repo links resolve across {n} markdown files")
    return 1 if bad else 0


def run_quickstart() -> int:
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    section = readme.split("## Quickstart", 1)
    if len(section) < 2:
        print("FAIL README.md has no '## Quickstart' section")
        return 1
    m = re.search(r"```(?:bash|sh)\n(.*?)```", section[1], re.S)
    if not m:
        print("FAIL no bash fence under README.md '## Quickstart'")
        return 1
    snippet = m.group(1).strip()
    print(f"running README quickstart:\n{snippet}\n")
    env = dict(os.environ, REPRO_INTERPRET="1")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(["bash", "-euo", "pipefail", "-c", snippet],
                          cwd=REPO, env=env)
    if proc.returncode:
        print(f"FAIL quickstart exited {proc.returncode}")
    else:
        print("ok: README quickstart ran clean")
    return proc.returncode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-quickstart", action="store_true",
                    help="execute the README quickstart snippet under "
                         "REPRO_INTERPRET=1 instead of checking links")
    args = ap.parse_args()
    sys.exit(run_quickstart() if args.run_quickstart else check_links())


if __name__ == "__main__":
    main()
