"""Paged-attention decode kernel coverage (kernels/paged_attention.py).

Three rings of parity, all in interpret mode (the kernel body executes
exactly as Mosaic would see it):
  * kernel vs the pure-jnp oracle (ref.paged_decode_ref) across page
    sizes {8, 16}, ragged per-lane depths, partial final pages, GQA
    group sizes, dtypes, and sliding windows — pools must match the
    XLA scatter bit-for-bit;
  * the self_attention paged branch: Pallas executor vs the bounded
    XLA fallback on identical inputs, and the bounded fallback vs the
    whole-window gather;
  * the serving engine: a kernel-executor paged engine must reproduce
    the dense backend's token stream over admit -> decode -> retire ->
    readmit traffic (lane/page reuse included).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import (assert_streams_equal, engine_spec, make_engine_parts,
                     mixed_traffic, run_and_collect)
from repro.kernels import ops, paged_attention, ref
from repro.models import attention as attn

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _paged_setup(seed, b, h, kv, d, ps, max_pages, pos, dtype=jnp.float32):
    """Random pools + a page table mapping each lane's live pages to
    distinct physical pages (page 0 reserved as scratch, as the backend
    lays it out)."""
    rng = np.random.default_rng(seed)
    n_pages = 1 + b * max_pages
    mk = lambda shape: jnp.asarray(rng.standard_normal(shape), dtype)
    q = mk((b, h, d))
    k_new, v_new = mk((b, kv, d)), mk((b, kv, d))
    k_pages, v_pages = (mk((n_pages, ps, kv, d)) for _ in range(2))
    table = np.zeros((b, max_pages), np.int32)
    nxt = 1
    for lane in range(b):
        for j in range(pos[lane] // ps + 1):
            table[lane, j] = nxt
            nxt += 1
    return (q, k_new, v_new, k_pages, v_pages, jnp.asarray(table),
            jnp.asarray(np.asarray(pos, np.int32)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ps", [8, 16])
@pytest.mark.parametrize("h,kv", [(4, 2), (2, 2)])
def test_kernel_matches_oracle(dtype, ps, h, kv):
    # ragged depths: page-boundary cases (0, ps-1, ps) + partial pages
    pos = [0, ps - 1, ps, 2 * ps + 3, 5 * ps - 1]
    args = _paged_setup(0, len(pos), h, kv, 16, ps, 6, pos, dtype)
    o, kp, vp = paged_attention.paged_decode(*args, interpret=True)
    ow, kw, vw = ref.paged_decode_ref(*args)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ow, np.float32), **TOL[dtype])
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(kw))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vw))


def test_kernel_bounded_walk_and_window():
    ps, pos = 8, [5, 17, 40]
    args = _paged_setup(1, 3, 4, 2, 16, ps, 8, pos)
    full, _, _ = paged_attention.paged_decode(*args, interpret=True)
    # depth-bounded walk: 6 pages cover max(pos)=40 -> identical output
    bounded, _, _ = paged_attention.paged_decode(*args, num_pages=6,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(bounded), np.asarray(full))
    w, _, _ = paged_attention.paged_decode(*args, window=10, interpret=True)
    ww, _, _ = ref.paged_decode_ref(*args, window=10)
    np.testing.assert_allclose(np.asarray(w), np.asarray(ww),
                               **TOL[jnp.float32])


def _attn_inputs(seed, b, d_model, h, kv, hd, ps, max_pages, pos):
    rng = np.random.default_rng(seed)
    p = attn.init_attention(jax.random.PRNGKey(seed), d_model, h, kv, hd)
    x = jnp.asarray(rng.standard_normal((b, 1, d_model)), jnp.float32)
    n_pages = 1 + b * max_pages
    pools = {"k": jnp.asarray(rng.standard_normal((n_pages, ps, kv, hd)),
                              jnp.float32),
             "v": jnp.asarray(rng.standard_normal((n_pages, ps, kv, hd)),
                              jnp.float32)}
    table = np.zeros((b, max_pages), np.int32)
    nxt = 1
    for lane in range(b):
        for j in range(pos[lane] // ps + 1):
            table[lane, j] = nxt
            nxt += 1
    cp = jnp.asarray(np.asarray(pos, np.int32))
    return p, x, pools, jnp.asarray(table), cp


@pytest.mark.parametrize("live_pages", [None, 4])
def test_self_attention_kernel_vs_xla(live_pages):
    """The full paged branch: Pallas executor vs XLA fallback on the same
    scatter + depth-bounded gather + attend step (RoPE included)."""
    ps, pos = 8, [3, 12, 25]
    p, x, pools, table, cp = _attn_inputs(3, 3, 32, 4, 2, 8, ps, 8, pos)
    kw = dict(n_heads=4, n_kv=2, rope_theta=10_000.0, q_pos=cp[:, None],
              cache_pos=cp, page_table=table, live_pages=live_pages)
    out_k, cache_k = attn.self_attention(p, x, cache=dict(pools),
                                         paged_kernel="kernel", **kw)
    out_x, cache_x = attn.self_attention(p, x, cache=dict(pools),
                                         paged_kernel="xla", **kw)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               rtol=2e-5, atol=2e-5)
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache_k[leaf]),
                                      np.asarray(cache_x[leaf]))


def test_xla_fallback_bounded_matches_whole_window():
    """Satellite fix: the XLA paged branch gathering only the live-page
    prefix must reproduce the historical whole-window gather."""
    ps, pos = 8, [3, 12, 25]
    p, x, pools, table, cp = _attn_inputs(4, 3, 32, 4, 2, 8, ps, 8, pos)
    kw = dict(n_heads=4, n_kv=2, rope_theta=10_000.0, q_pos=cp[:, None],
              cache_pos=cp, page_table=table, paged_kernel="xla")
    out_full, _ = attn.self_attention(p, x, cache=dict(pools),
                                      live_pages=None, **kw)
    out_bound, _ = attn.self_attention(p, x, cache=dict(pools),
                                       live_pages=4, **kw)
    np.testing.assert_allclose(np.asarray(out_bound), np.asarray(out_full),
                               rtol=2e-6, atol=2e-6)


def test_undersized_walk_never_corrupts_pools():
    """An undersized num_pages bound is a caller bug (the scheduler's
    live_page_bound always covers the batch) — it may truncate the
    attended window, but it must never flush garbage over live K/V
    pages: the write-back page is clamped into the walk and degrades to
    an identity rewrite."""
    ps, pos = 8, [5, 17, 40]                  # deepest lane needs 6 pages
    args = _paged_setup(7, 3, 4, 2, 16, ps, 8, pos)
    q, k_new, v_new, k_pages, v_pages, table, cp = args
    _, kp, vp = paged_attention.paged_decode(*args, num_pages=2,
                                             interpret=True)
    # lane 0 (depth 5, inside the walk) scatters its token normally;
    # lanes 1 and 2 are beyond the walk and must leave the pools intact
    want_k = k_pages.at[table[0, 0], 5].set(k_new[0])
    want_v = v_pages.at[table[0, 0], 5].set(v_new[0])
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(want_k))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(want_v))


def test_self_attention_kernel_bf16_scores_tolerance():
    """attn_bf16_scores halves the XLA chain's score-tensor HBM traffic;
    the kernel's score tile never leaves VMEM, so it keeps f32 stats —
    parity with the bf16-scores XLA path is tolerance-level (standard
    flash-kernel numerics), pinned here so the divergence stays bounded."""
    ps, pos = 8, [3, 12, 25]
    p, x, pools, table, cp = _attn_inputs(5, 3, 32, 4, 2, 8, ps, 8, pos)
    p = {k: v.astype(jnp.bfloat16) for k, v in p.items()}
    x = x.astype(jnp.bfloat16)
    pools = {k: v.astype(jnp.bfloat16) for k, v in pools.items()}
    kw = dict(n_heads=4, n_kv=2, rope_theta=10_000.0, q_pos=cp[:, None],
              cache_pos=cp, page_table=table, bf16_scores=True)
    out_k, _ = attn.self_attention(p, x, cache=dict(pools),
                                   paged_kernel="kernel", **kw)
    out_x, _ = attn.self_attention(p, x, cache=dict(pools),
                                   paged_kernel="xla", **kw)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_x, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_paged_kernel_mode_guard():
    with pytest.raises(ValueError):
        attn._use_paged_kernel("mosaic")


def test_live_page_bound_covered_by_warm_buckets():
    """Every bound the scheduler can request must be in the set
    warm_decode pre-compiles, or a jit compile lands mid-measurement."""
    from repro.serving.scheduler import live_page_bound, live_page_buckets
    for cap in (1, 3, 4, 5, 8, 16):
        buckets = live_page_buckets(cap)
        for pos in range(cap * 8):
            b = live_page_bound(pos, 8, cap)
            assert b in buckets and b * 8 > pos


def test_repro_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    assert ops._interpret()
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert not ops._interpret()
    monkeypatch.delenv("REPRO_INTERPRET")
    assert ops._interpret() == (jax.default_backend() == "cpu")


# ---------------------------------------------------------------------------
# engine-level: kernel executor vs dense backend token stream
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_parts():
    return make_engine_parts()


@pytest.mark.parametrize("page_size", [8, 16])
def test_kernel_engine_stream_matches_dense(engine_parts, page_size):
    """6 requests through 2 slots: every lane is retired and readmitted,
    pages are freed and reused — the Pallas-executor paged engine must
    emit the dense backend's exact token stream."""
    cfg, params, dsg = engine_parts
    dense_out = run_and_collect(engine_spec(*engine_parts),
                                mixed_traffic(cfg))
    kcfg = cfg.replace(paged_attn_kernel="kernel")
    kernel_out, eng = run_and_collect(
        engine_spec(kcfg, params, dsg, cache_backend="paged",
                    page_size=page_size, cache_tokens=80),
        mixed_traffic(cfg), return_engine=True)
    assert_streams_equal(dense_out, kernel_out, "kernel engine vs dense")
    alloc = eng.backend.allocator
    assert alloc.free_pages == alloc.n_pages - alloc.reserved
