"""Fused device-resident decode loop (ISSUE 9): `decode_chunk` micro-
steps scanned inside ONE jitted dispatch, with per-lane EOS / budget
freezing on device and host bookkeeping (commit_chunk) lagging a full
chunk behind.

The contract under test is the same determinism wall every previous PR
leaned on, extended along a new axis: at temperature 0 the merged
per-request token streams must be BITWISE-IDENTICAL across
chunk in {1, 2, 8} x {dense, paged} x {sequential, threaded} executors —
including EOS landing mid-chunk, retire/readmit across a chunk boundary,
DSG refresh cadence, and a chaos kill landing between chunks.  What may
legitimately differ is scheduling (readmission waits for a chunk
boundary) and therefore per-step lane occupancy — never stream content.
"""
import numpy as np
import pytest

from harness import (assert_streams_equal, engine_spec, make_engine_parts,
                     mixed_traffic, run_and_collect)
from repro.runtime.fault_tolerance import ReplicaFault, ServingFaultInjector
from repro.serving.dsg_runtime import DSGServingConfig
from repro.serving.parallel_exec import ShardedExecutor
from repro.serving.router import FaultToleranceConfig, Router
from repro.serving.scheduler import Request, ServingEngine
from repro.serving.workload import warmup_router

CHUNKS = (2, 8)

PAGED_KW = dict(cache_backend="paged", page_size=8, cache_tokens=160)


@pytest.fixture(scope="module")
def parts():
    return make_engine_parts()


@pytest.fixture(scope="module")
def ref_streams(parts):
    """chunk=1 single-engine reference for the canonical mixed traffic
    (6 requests over 2 slots — every run retires and readmits lanes,
    which a chunked engine may only do at chunk boundaries)."""
    cfg, params, dsg = parts
    return run_and_collect(engine_spec(cfg, params, dsg),
                           mixed_traffic(cfg))


# -- chunk x backend stream equality (bare engine) ---------------------------

@pytest.mark.parametrize("chunk", CHUNKS)
@pytest.mark.parametrize("backend_kw", [{}, PAGED_KW],
                         ids=["dense", "paged"])
def test_chunked_streams_bitwise_equal(parts, ref_streams, chunk,
                                       backend_kw):
    cfg, params, dsg = parts
    got = run_and_collect(
        engine_spec(cfg, params, dsg, decode_chunk=chunk, **backend_kw),
        mixed_traffic(cfg))
    assert_streams_equal(ref_streams, got, f"chunk={chunk}")


def test_chunked_counters_and_paged_pool(parts):
    """Accounting: a solo request decodes the same number of micro-steps
    and tokens regardless of chunking (no co-residents, so occupancy is
    identical), and the paged pool drains back to its idle level — the
    pre-reserved chunk pages (ensure_range) are clamped to the lane's
    budget and all returned at retirement."""
    cfg, params, dsg = parts
    counts = {}
    for chunk in (1, 8):
        req = [Request(uid=0, prompt=np.arange(5, dtype=np.int32) + 3,
                       max_new=11)]
        streams, eng = run_and_collect(
            engine_spec(cfg, params, dsg, decode_chunk=chunk, **PAGED_KW),
            req, return_engine=True)
        counts[chunk] = (eng.steps, eng.decode_tokens,
                         eng.backend.allocator.free_pages,
                         int(eng.backend._resv.sum()))
    assert counts[1] == counts[8]
    assert counts[1][1] == 11          # max_new tokens decoded
    assert counts[1][3] == 0           # no leaked reservations


# -- EOS mid-chunk -----------------------------------------------------------

def test_eos_mid_chunk(parts):
    """Pick a stop token straight out of the greedy reference streams so
    generation really does hit EOS, at positions that are NOT chunk
    boundaries for chunk 8 — the device done-mask must freeze the lane
    at the right micro-step and the host must retire it from the lagged
    commit."""
    cfg, params, dsg = parts
    base = run_and_collect(engine_spec(cfg, params, dsg),
                           mixed_traffic(cfg))
    # a token emitted mid-stream by the longest reference stream
    uid = max(base, key=lambda u: len(base[u]))
    eos = base[uid][len(base[uid]) // 2]

    def traffic():
        reqs = mixed_traffic(cfg)
        for r in reqs:
            r.eos_id = eos
        return reqs

    ref = run_and_collect(engine_spec(cfg, params, dsg), traffic())
    assert any(r and r[-1] == eos and len(r) < len(base[u])
               for u, r in ref.items()), "chosen eos never cut a stream"
    for chunk in CHUNKS:
        for backend_kw in ({}, PAGED_KW):
            got = run_and_collect(
                engine_spec(cfg, params, dsg, decode_chunk=chunk,
                            **backend_kw),
                traffic())
            assert_streams_equal(ref, got, f"eos chunk={chunk}")


# -- executors ---------------------------------------------------------------

@pytest.mark.parametrize("exec_mode", ["sequential", "threaded"])
@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_router_executors(parts, ref_streams, chunk, exec_mode):
    """Chunked engines behind the Router: the sequential and threaded
    executors drive ServingEngine.step(), so the fused path flows
    through unchanged — streams stay bitwise equal to the chunk=1
    single-engine reference across replicas."""
    cfg, params, dsg = parts
    got = run_and_collect(
        engine_spec(cfg, params, dsg, n_replicas=2, exec_mode=exec_mode,
                    decode_chunk=chunk, **PAGED_KW),
        mixed_traffic(cfg))
    assert_streams_equal(ref_streams, got, f"{exec_mode} chunk={chunk}")


def test_chunked_sharded_executor(parts, ref_streams):
    """The sharded executor vmaps the SAME chunked step bodies over the
    replica axis — one dispatch per (chunk x replicas) tick."""
    cfg, params, dsg = parts
    got = run_and_collect(
        engine_spec(cfg, params, dsg, n_replicas=2, exec_mode="sharded",
                    decode_chunk=8),
        mixed_traffic(cfg))
    assert_streams_equal(ref_streams, got, "sharded chunk=8")


def test_sharded_rejects_mixed_chunks(parts):
    cfg, params, dsg = parts
    engines = [ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                             prompt_bucket=32, decode_chunk=c)
               for c in (1, 8)]
    with pytest.raises(ValueError, match="homogeneous decode_chunk"):
        ShardedExecutor(engines)


# -- DSG refresh cadence -----------------------------------------------------

@pytest.mark.parametrize("chunk", CHUNKS)
def test_dsg_refresh_cadence_invariant(parts, chunk):
    """Per-lane refresh cadence is emitted-token count mod
    refresh_interval; with chunk | interval and admission pinned to
    chunk boundaries, a due point can only land on a chunk's LAST
    micro-step — whose FFN inputs are exactly the ones the chunk=1
    refresh scores, so patterns and streams match bitwise."""
    cfg, params, dsg = parts
    scfg = DSGServingConfig(refresh_interval=8)
    ref = run_and_collect(engine_spec(cfg, params, dsg, dsg_serving=scfg),
                          mixed_traffic(cfg))
    for backend_kw in ({}, PAGED_KW):
        got = run_and_collect(
            engine_spec(cfg, params, dsg, dsg_serving=scfg,
                        decode_chunk=chunk, **backend_kw),
            mixed_traffic(cfg))
        assert_streams_equal(ref, got, f"dsg chunk={chunk}")


def test_dsg_chunk_must_divide_refresh_interval(parts):
    cfg, params, dsg = parts
    with pytest.raises(ValueError, match="refresh_interval"):
        ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                      prompt_bucket=32, decode_chunk=3,
                      dsg_serving=DSGServingConfig(refresh_interval=8))


def test_decode_chunk_validation(parts):
    cfg, params, dsg = parts
    with pytest.raises(ValueError, match="decode_chunk"):
        ServingEngine(cfg, params, dsg, n_slots=2, decode_chunk=0)


# -- chaos kill between chunks -----------------------------------------------

def test_chaos_kill_lands_on_chunk_boundary(parts, ref_streams):
    """A kill keyed at step 5 lands mid-chunk for chunk=8; the injector
    fires it at the FIRST step boundary past it (the >= keying) — the
    only place a chunked engine can contain a fault — and failover
    replays the reclaimed requests bitwise."""
    cfg, params, dsg = parts
    inj = ServingFaultInjector([ReplicaFault(replica=0, step=5)])
    router = Router(cfg, params, dsg, n_replicas=2, policy="round_robin",
                    n_slots=2, max_seq=64, prompt_bucket=32,
                    decode_chunk=8,
                    fault_tolerance=FaultToleranceConfig(
                        max_replica_restarts=1))
    warmup_router(router, cfg.vocab)
    inj.attach(router.engines)
    for r in mixed_traffic(cfg):
        router.submit(r)
    done = router.run(max_steps=8000)
    assert len(inj.log) == 1           # the mid-chunk key still fired
    # it fired at a chunk boundary: the engine's counter had already
    # jumped past the keyed step when on_step observed it
    assert router.health[0].restarts == 1
    assert_streams_equal(ref_streams,
                         {u: list(r.output) for u, r in done.items()},
                         "chaos kill between chunks")
