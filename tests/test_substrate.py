"""Substrate tests: optimizer, gradient compression, data pipeline,
checkpointing, fault tolerance, elastic planning, sharding specs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.data import synthetic
from repro.models import api, specs
from repro.optim import adamw, compress
from repro.parallel.sharding import Axes
from repro.runtime.elastic import plan_after_loss
from repro.runtime.fault_tolerance import (FaultInjector, StragglerMonitor,
                                           run_with_restarts)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup=1,
                            total_steps=200, schedule="const")
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw.init_opt(params, use_master=False)
    target = jnp.array([1.0, 1.0, 1.0])
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw.apply_updates(params, g, opt, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping():
    cfg = adamw.AdamWConfig(clip_norm=1.0, warmup=1)
    params = {"w": jnp.zeros(4)}
    opt = adamw.init_opt(params, False)
    _, _, m = adamw.apply_updates(params, {"w": jnp.full(4, 100.0)}, opt, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup=10, total_steps=100,
                            schedule="cosine")
    assert float(adamw.lr_at(cfg, 0)) < 0.2
    assert float(adamw.lr_at(cfg, 10)) == pytest.approx(1.0, abs=0.05)
    assert float(adamw.lr_at(cfg, 100)) < 0.01


def test_master_weights_bf16():
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    opt = adamw.init_opt(params, use_master=True)
    assert opt["master"]["w"].dtype == jnp.float32
    cfg = adamw.AdamWConfig(lr=1e-3, warmup=1)
    g = {"w": jnp.full(8, 1e-4, jnp.bfloat16)}
    # tiny updates accumulate in fp32 master even when bf16 can't express
    for _ in range(10):
        params, opt, _ = adamw.apply_updates(params, g, opt, cfg)
    assert float(jnp.abs(opt["master"]["w"] - 1.0).max()) > 0
    assert params["w"].dtype == jnp.bfloat16


def test_zero1_specs_add_data_axis():
    from jax.sharding import PartitionSpec as P
    ps = {"a": P(None, "model"), "b": P()}
    params = {"a": jnp.zeros((32, 64)), "b": jnp.zeros((7,))}
    zs = adamw.zero1_specs(ps, params)
    assert zs["a"] == P("data", "model")
    assert zs["b"] == P()          # 7 not divisible -> untouched


# ---------------------------------------------------------------------------
# ternary gradient compression (beyond-paper §7.3)
# ---------------------------------------------------------------------------

def test_ternarize_codes():
    g = jnp.array([3.0, -2.5, 0.01, 0.0, 5.0])
    codes, scale = compress.ternarize(g)
    assert set(np.unique(np.asarray(codes))) <= {-1.0, 0.0, 1.0}
    assert float(scale) > 0


def test_error_feedback_telescopes():
    """sum of decoded over steps -> sum of raw gradients (error feedback
    makes compression lossless in the telescoping sum)."""
    key = jax.random.PRNGKey(0)
    gs = jax.random.normal(key, (50, 64))
    err = jnp.zeros(64)
    decoded_sum = jnp.zeros(64)
    for i in range(50):
        dec, err = compress.compress_with_feedback(gs[i], err)
        decoded_sum += dec
    true_sum = gs.sum(0)
    # residual equals the final error buffer exactly
    np.testing.assert_allclose(np.asarray(true_sum - decoded_sum),
                               np.asarray(err), rtol=1e-4, atol=1e-4)


def test_compressed_sgd_converges():
    w = jnp.array([4.0, -4.0])
    err = jnp.zeros(2)
    for _ in range(300):
        g = 2 * w
        dec, err = compress.compress_with_feedback(g, err)
        w = w - 0.05 * dec
    assert float(jnp.abs(w).max()) < 0.1


def test_wire_bytes_reduction():
    g = jnp.zeros(1024)
    assert compress.wire_bytes(g) < g.size * 4 / 10


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic():
    a = synthetic.batch_at(7, global_batch=4, seq_len=16, vocab=100)
    b = synthetic.batch_at(7, global_batch=4, seq_len=16, vocab=100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_data_labels_are_shifted():
    b = synthetic.batch_at(0, global_batch=2, seq_len=32, vocab=50)
    assert b["tokens"].shape == (2, 32) and b["labels"].shape == (2, 32)


def test_data_hosts_disjoint():
    h0 = synthetic.batch_at(3, global_batch=8, seq_len=16, vocab=1000,
                            host_index=0, host_count=2)
    h1 = synthetic.batch_at(3, global_batch=8, seq_len=16, vocab=1000,
                            host_index=1, host_count=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 100))
def test_data_in_vocab(step, seed):
    b = synthetic.batch_at(step, global_batch=2, seq_len=8, vocab=37,
                           seed=seed)
    assert int(b["tokens"].max()) < 37 and int(b["tokens"].min()) >= 0


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"step": jnp.int32(5), "m": [jnp.ones(4)]}}


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(10, t, meta={"note": "x"})
    restored, step, meta = mgr.restore(t)
    assert step == 10 and meta["note"] == "x"
    np.testing.assert_array_equal(restored["params"]["w"],
                                  np.asarray(t["params"]["w"]))


def test_ckpt_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.latest_step() == 4
    assert mgr.steps() == [3, 4]


def test_ckpt_ignores_partial_writes(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    os.makedirs(tmp_path / "step_2.tmp")        # simulated crash mid-write
    (tmp_path / "step_2.tmp" / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1


def test_ckpt_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_ckpt_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(7, _tree())
    mgr.wait()
    assert mgr.latest_step() == 7


# ---------------------------------------------------------------------------
# fault tolerance / stragglers / elastic
# ---------------------------------------------------------------------------

def test_run_with_restarts_recovers(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"x": jnp.float32(0.0)}

    def step_fn(st, batch):
        return {"x": st["x"] + 1.0}, {"loss": st["x"]}

    injector = FaultInjector(fail_at=(7, 13))
    state, hist = run_with_restarts(
        step_fn=step_fn, state=state, make_batch=lambda s: None,
        ckpt=mgr, total_steps=20, ckpt_every=5, injector=injector)
    assert float(state["x"]) == 20.0           # replay is exact
    assert len(hist) >= 20


def test_run_with_restarts_gives_up():
    def bad(st, batch):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        run_with_restarts(step_fn=bad, state={}, make_batch=lambda s: None,
                          ckpt=None, total_steps=3, max_retries=2)


def test_straggler_detection():
    mon = StragglerMonitor(window=16, factor=1.5)
    for i in range(10):
        mon.record(i, 1.0)
    assert mon.record(10, 2.0) is True
    assert mon.record(11, 1.05) is False
    assert len(mon.flagged) == 1


def test_elastic_plan():
    p = plan_after_loss(512 - 16, model=16)    # lost one 16-chip host
    assert p.model == 16 and p.data == 16 and p.n_devices == 256
    p2 = plan_after_loss(300, model=16)
    assert p2.data == 16
    with pytest.raises(RuntimeError):
        plan_after_loss(8, model=16)


# ---------------------------------------------------------------------------
# sharding specs: static divisibility audit for every arch x mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(configs.ARCHS))
@pytest.mark.parametrize("axes,n_model,sizes", [
    (Axes(batch=("data",), model="model"), 16, {"data": 16, "model": 16}),
    (Axes(batch=("pod", "data"), model="model"), 16,
     {"pod": 2, "data": 16, "model": 16}),
])
def test_param_specs_divisible(arch, axes, n_model, sizes):
    """Every sharded dim of every parameter divides its mesh axis — the
    static proof that the full configs lower on the production meshes."""
    cfg = configs.get_config(arch)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: api.init_model(key, cfg))
    pspecs = specs.param_specs(params, cfg, axes, n_model)

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: hasattr(x, "index"))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        entries = tuple(spec)
        for dim_idx, entry in enumerate(entries):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for n in names:
                total *= sizes[n]
            assert leaf.shape[dim_idx] % total == 0, (
                f"{arch}: {path} dim {dim_idx} ({leaf.shape}) not divisible "
                f"by {total} ({spec})")
