"""Model-component unit tests: chunked-vs-direct attention parity,
Mamba2/mLSTM chunked-scan vs naive recurrence, MoE dispatch invariants,
RoPE properties, decode-vs-parallel consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.dsg_linear import DSGConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import apply_rope


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def test_chunked_attention_matches_direct():
    key = jax.random.PRNGKey(0)
    b, s, h, d = 2, 128, 4, 32
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, s, h, d))
               for i in range(3))
    pos = jnp.arange(s)
    direct = attn.attend_direct(q, k, v, pos, pos, causal=True, window=0)
    chunked = attn.attend_chunked(q, k, v, pos, pos, causal=True, window=0,
                                  q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_windowed():
    key = jax.random.PRNGKey(1)
    b, s, h, d = 1, 64, 2, 16
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, s, h, d))
               for i in range(3))
    pos = jnp.arange(s)
    direct = attn.attend_direct(q, k, v, pos, pos, causal=True, window=16)
    chunked = attn.attend_chunked(q, k, v, pos, pos, causal=True, window=16,
                                  q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=2e-5, atol=2e-5)


def test_repeat_kv():
    k = jnp.arange(2 * 4 * 2 * 3, dtype=jnp.float32).reshape(2, 4, 2, 3)
    r = attn.repeat_kv(k, 6)
    assert r.shape == (2, 4, 6, 3)
    np.testing.assert_array_equal(r[:, :, 0], r[:, :, 2])
    np.testing.assert_array_equal(r[:, :, 3], r[:, :, 5])


def test_decode_matches_parallel_forward():
    """Prefill+decode over a cache must agree with a single parallel pass."""
    key = jax.random.PRNGKey(2)
    d, h, kv, hd, s = 32, 4, 2, 8, 12
    p = attn.init_attention(key, d, h, kv, hd)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, s, d))
    full, _ = attn.self_attention(p, x, n_heads=h, n_kv=kv,
                                  rope_theta=1e4, q_pos=jnp.arange(s))
    cache = {"k": jnp.zeros((1, s, kv, hd)), "v": jnp.zeros((1, s, kv, hd))}
    _, cache = attn.self_attention(p, x[:, :8], n_heads=h, n_kv=kv,
                                   rope_theta=1e4, q_pos=jnp.arange(8),
                                   cache=cache, cache_pos=0)
    outs = []
    for i in range(8, s):
        o, cache = attn.self_attention(
            p, x[:, i:i + 1], n_heads=h, n_kv=kv, rope_theta=1e4,
            q_pos=jnp.arange(i, i + 1), cache=cache, cache_pos=i)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 8:]),
                               rtol=2e-4, atol=2e-4)


def test_rope_preserves_norm_and_relative_positions():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 16, 2, 32))
    y = apply_rope(x, jnp.arange(16)[None], 1e4)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # inner products depend only on relative distance
    q = apply_rope(x, jnp.arange(16)[None], 1e4)
    k = apply_rope(x, jnp.arange(16)[None], 1e4)
    d1 = jnp.einsum("bshd,bshd->bsh", q[:, 2:3], k[:, 0:1])
    q2 = apply_rope(x, 5 + jnp.arange(16)[None], 1e4)
    k2 = apply_rope(x, 5 + jnp.arange(16)[None], 1e4)
    d2 = jnp.einsum("bshd,bshd->bsh", q2[:, 2:3], k2[:, 0:1])
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2: chunked scan vs naive recurrence
# ---------------------------------------------------------------------------

def _naive_ssd(xh, dt, a, bmat, cmat):
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    hst = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        hst = hst * jnp.exp(a[:, t])[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", bmat[:, t], xh[:, t] * dt[:, t, :, None])
        ys.append(jnp.einsum("bn,bhnp->bhp", cmat[:, t], hst))
    return jnp.stack(ys, axis=1), hst


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (24, 24)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    key = jax.random.PRNGKey(4)
    b, h, p, n = 2, 3, 4, 5
    dm = m2.Mamba2Dims(d=0, d_in=h * p, heads=h, head_dim=p, n=n,
                       chunk=chunk)
    xh = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    a = -0.5 * dt
    bmat = jax.random.normal(jax.random.fold_in(key, 2), (b, s, n))
    cmat = jax.random.normal(jax.random.fold_in(key, 3), (b, s, n))
    y, hf = m2.ssd_chunked(xh, dt, a, bmat, cmat, dm)
    y_ref, hf_ref = _naive_ssd(xh, dt, a, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_ref),
                               rtol=2e-4, atol=2e-4)


def test_mamba_decode_continues_prefill():
    cfg_dm = m2.dims(16, 2, 8, 4, 8)
    p = m2.init_mamba2(jax.random.PRNGKey(5), cfg_dm)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, 16))
    full, _ = m2.mamba2_forward(p, x, cfg_dm)
    _, st = m2.mamba2_forward(p, x[:, :15], cfg_dm)
    step, _ = m2.mamba2_forward(p, x[:, 15:16], cfg_dm, state=st)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, 15]), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# mLSTM chunked vs recurrence
# ---------------------------------------------------------------------------

def test_mlstm_chunked_matches_recurrence():
    key = jax.random.PRNGKey(7)
    b, s, h, dk, dv = 1, 16, 2, 4, 4
    dm = xl.MLSTMDims(d=h * dk, heads=h, dk=dk, dv=dv, chunk=4)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, s, h, dk))
               for i in range(3))
    log_f = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(key, 3), (b, s, h)) + 2.0)
    i_gate = jnp.exp(jax.random.normal(jax.random.fold_in(key, 4),
                                       (b, s, h)) * 0.3)
    y, _ = xl.mlstm_chunked(q, k, v, log_f, i_gate, dm)
    # naive recurrence
    import math
    c = jnp.zeros((b, h, dk, dv))
    n = jnp.ones((b, h, dk))
    outs = []
    for t in range(s):
        f = jnp.exp(log_f[:, t])
        c = c * f[..., None, None] + i_gate[:, t][..., None, None] * \
            jnp.einsum("bhd,bhv->bhdv", k[:, t], v[:, t])
        n = n * f[..., None] + i_gate[:, t][..., None] * k[:, t]
        qs = q[:, t] / math.sqrt(dk)
        num = jnp.einsum("bhd,bhdv->bhv", qs, c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)), 1.0)
        outs.append(num / den[..., None])
    want = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), topk=st.integers(1, 3))
def test_moe_dispatch_conservation(seed, topk):
    """With ample capacity, every token's output is a convex combination of
    expert outputs (weights sum to 1) — checked against a dense reference."""
    key = jax.random.PRNGKey(seed)
    d, e, fe, t = 8, 4, 16, 12
    p = moe_mod.init_moe(key, d, e, fe, n_shared=0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, t, d))
    y, aux = moe_mod.moe_ffn(p, x, n_experts=e, top_k=topk,
                             capacity_factor=8.0, dsg=DSGConfig(),
                             aux_kind="probs")
    # dense reference: route every token through its top-k experts
    x2d = x.reshape(-1, d)
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tw, te = jax.lax.top_k(probs, topk)
    tw = tw / tw.sum(-1, keepdims=True)
    want = jnp.zeros_like(x2d)
    for kk in range(topk):
        for ei in range(e):
            sel = (te[:, kk] == ei)
            g = jax.nn.silu(x2d @ p["w_gate"][ei]) * (x2d @ p["w_up"][ei])
            out_e = g @ p["w_down"][ei]
            want = want + jnp.where(sel[:, None], out_e * tw[:, kk:kk + 1],
                                    0.0)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)),
                               np.asarray(want), rtol=5e-4, atol=5e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    """With capacity 1, overflow tokens are dropped (zero output), not
    corrupted."""
    key = jax.random.PRNGKey(9)
    d, e, fe, t = 8, 2, 16, 16
    p = moe_mod.init_moe(key, d, e, fe, n_shared=0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, t, d))
    y, _ = moe_mod.moe_ffn(p, x, n_experts=e, top_k=1,
                           capacity_factor=0.125, dsg=DSGConfig(),
                           aux_kind="probs")
    norms = np.asarray(jnp.linalg.norm(y.reshape(-1, d), axis=-1))
    assert (norms == 0.0).sum() >= t - 2 * max(1, int(0.125 * t / e))
