"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward +
train step and a prefill/decode roundtrip on CPU, asserting shapes and
finiteness.  The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SMOKE_SHAPE, ShapeConfig
from repro.models import api

ARCHS = list(configs.ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke_config(arch)
            key = jax.random.PRNGKey(0)
            params = api.init_model(key, cfg)
            dsg = api.init_dsg(jax.random.PRNGKey(1), params, cfg)
            cache[arch] = (cfg, params, dsg)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, built):
    cfg, params, dsg = built(arch)
    batch = api.make_inputs(cfg, SMOKE_SHAPE, concrete=True)
    loss, grads = jax.value_and_grad(
        lambda p: api.train_loss(p, dsg, cfg, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_roundtrip(arch, built):
    cfg, params, dsg = built(arch)
    shape = ShapeConfig("p", 16, 2, "prefill")
    inputs = api.make_inputs(cfg, shape, concrete=True)
    cache = api.make_cache(cfg, 2, 32)
    logits, state = api.prefill(params, dsg, cfg, inputs, cache)
    assert logits.shape == (2, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        logits, state = api.decode_step(params, dsg, cfg, tok, state,
                                        jnp.int32(16 + i))
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_dsg_off_still_works(arch, built):
    cfg, _, _ = built(arch)
    cfg_off = cfg.replace(dsg=cfg.dsg._replace(enabled=False))
    params = api.init_model(jax.random.PRNGKey(0), cfg_off)
    batch = api.make_inputs(cfg_off, SMOKE_SHAPE, concrete=True)
    loss = api.train_loss(params, None, cfg_off, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_dsg_refresh_shapes(arch, built):
    cfg, params, dsg = built(arch)
    if dsg is None:
        pytest.skip("dsg disabled")
    new = api.refresh_dsg(dsg, params, cfg)
    for a, b in zip(jax.tree.leaves(dsg), jax.tree.leaves(new)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_full_configs_match_assignment():
    """The exact architecture numbers from the assignment sheet."""
    c = configs.get_config("mistral-nemo-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (40, 5120, 32, 8, 14336, 131072)
    c = configs.get_config("internlm2-1.8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (24, 2048, 16, 8, 8192, 92544)
    c = configs.get_config("llama3.2-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (28, 3072, 24, 8, 8192, 128256)
    c = configs.get_config("phi3-mini-3.8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (32, 3072, 32, 32, 8192, 32064)
    c = configs.get_config("deepseek-moe-16b")
    assert (c.moe_experts, c.moe_topk, c.moe_shared, c.moe_d_ff) == \
        (64, 6, 2, 1408)
    assert (c.n_layers, c.d_model, c.vocab) == (28, 2048, 102400)
    c = configs.get_config("llama4-scout-17b-a16e")
    assert (c.moe_experts, c.moe_topk) == (16, 1)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.vocab) == \
        (48, 5120, 40, 8, 202048)
    c = configs.get_config("xlstm-350m")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == \
        (24, 1024, 4, 50304)
    c = configs.get_config("llava-next-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (60, 7168, 56, 8, 20480, 64000)
    c = configs.get_config("whisper-large-v3")
    assert (c.n_layers, c.enc_layers, c.d_model, c.n_heads, c.d_ff) == \
        (32, 32, 1280, 20, 5120)
    c = configs.get_config("zamba2-7b")
    assert (c.d_model, c.n_heads, c.d_ff, c.vocab, c.ssm_state) == \
        (3584, 32, 14336, 32000, 64)
