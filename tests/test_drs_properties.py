"""Hypothesis property tests for the DRS selection core (core/drs.py)
and the mask algebra (core/masks.py) the serving runtime leans on.

Scores are generated from a drawn PRNG seed (hypothesis shrinks the
seed), so rows are generically distinct floats; tie behavior gets its
own deterministic test.  These are host/jit-free pure functions —
hundreds of examples run in milliseconds."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import drs, masks

_SEED = st.integers(0, 2**32 - 1)
_ROWS = st.integers(1, 5)
_G = st.sampled_from([2, 4, 8, 16])
_BLOCK = st.sampled_from([4, 8])
_GAMMA = st.sampled_from([0.0, 0.25, 0.5, 0.75])


def _scores(seed, rows, g):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((rows, g)).astype(np.float32)


# ---------------------------------------------------------------------------
# select_mask threshold modes
# ---------------------------------------------------------------------------

@settings(max_examples=150)
@given(_SEED, _ROWS, _G, _BLOCK, _GAMMA)
def test_topk_density_respects_gamma(seed, rows, g, block, gamma):
    """topk mode: every row keeps at least keep_groups(gamma) groups, and
    EXACTLY that many when its scores are distinct (ties only widen)."""
    cfg = drs.DRSConfig(gamma=gamma, block=block, threshold_mode="topk")
    n_out = g * block
    s = _scores(seed, rows, g)
    mask, ema = drs.select_mask(jnp.asarray(s), n_out, cfg)
    assert ema is None
    k = drs.keep_groups(n_out, cfg)
    counts = np.asarray(mask).sum(axis=-1)
    assert (counts >= k).all()
    for r in range(rows):
        if len(np.unique(s[r])) == g:
            assert counts[r] == k
    assert float(masks.density(mask)) >= k / g - 1e-6


@settings(max_examples=150)
@given(_SEED, _ROWS, _G, _BLOCK, st.sampled_from([0.25, 0.5, 0.75]))
def test_shared_mode_uses_row0_topk_threshold(seed, rows, g, block,
                                              gamma):
    """shared mode == thresholding EVERY row at row 0's k-th largest
    score (paper Appendix B inter-sample sharing), including rows whose
    own top-k threshold would differ."""
    cfg = drs.DRSConfig(gamma=gamma, block=block,
                        threshold_mode="shared")
    n_out = g * block
    s = _scores(seed, rows, g)
    k = drs.keep_groups(n_out, cfg)
    mask, _ = drs.select_mask(jnp.asarray(s), n_out, cfg)
    got = np.asarray(mask) > 0
    if k >= g:
        assert got.all()
        return
    thr = np.sort(s[0])[g - k]          # row 0's k-th largest
    assert np.array_equal(got, s >= thr)


@settings(max_examples=150)
@given(_SEED, _ROWS, _G, _BLOCK)
def test_ema_deterministic_and_follows_decay(seed, rows, g, block):
    """ema mode is a pure function of (scores, carried threshold): same
    inputs -> identical mask and new EMA; the None seed-call adopts the
    batch threshold, and a carried EMA decays toward it."""
    cfg = drs.DRSConfig(gamma=0.5, block=block, threshold_mode="ema",
                        ema_decay=0.9)
    n_out = g * block
    s = jnp.asarray(_scores(seed, rows, g))
    k = drs.keep_groups(n_out, cfg)
    m1, e1 = drs.select_mask(s, n_out, cfg)
    m2, e2 = drs.select_mask(s, n_out, cfg)
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    if k >= g:                           # early all-ones path, EMA None
        assert e1 is None and e2 is None
        return
    assert float(e1) == float(e2)
    # seed call: EMA = decay*t + (1-decay)*t = t, the batch mean top-k
    # threshold (f32 mean over rows)
    per_row = np.sort(np.asarray(s), axis=-1)[:, g - k]
    thr_now = float(jnp.mean(jnp.asarray(per_row)))
    assert np.isclose(float(e1), thr_now, rtol=1e-5)
    assert np.array_equal(np.asarray(m1),
                          np.asarray(s) >= thr_now)
    # carried threshold: mask thresholds at the CARRIED value, new EMA
    # decays toward the batch threshold
    carried = jnp.asarray(thr_now + 1.0, jnp.float32)
    m3, e3 = drs.select_mask(s, n_out, cfg, ema_threshold=carried)
    assert np.array_equal(np.asarray(m3),
                          np.asarray(s) >= float(carried))
    assert np.isclose(float(e3), 0.9 * float(carried) + 0.1 * thr_now,
                      rtol=1e-5)


def test_topk_all_tied_scores_keep_everything():
    """Degenerate ties: every score equal -> threshold equals them all,
    the >= comparison keeps every group (never fewer than k)."""
    cfg = drs.DRSConfig(gamma=0.5, block=4, threshold_mode="topk")
    mask, _ = drs.select_mask(jnp.ones((3, 8)), 32, cfg)
    assert np.asarray(mask).all()


# ---------------------------------------------------------------------------
# mask algebra round trips
# ---------------------------------------------------------------------------

@settings(max_examples=150)
@given(_SEED, _ROWS, _G, _BLOCK)
def test_apply_expanded_matches_explicit_expansion(seed, rows, g, block):
    """apply_expanded == multiply by jnp.repeat-expanded mask, exactly
    (0/1 multiplies are exact in f32); re-applying the same mask is a
    no-op, and the all-ones mask is the identity."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, g * block)).astype(np.float32)
    gm = rng.integers(0, 2, (rows, g)).astype(np.float32)
    y = np.asarray(masks.apply_expanded(jnp.asarray(x),
                                        jnp.asarray(gm), block))
    assert np.array_equal(y, x * np.repeat(gm, block, axis=-1))
    y2 = np.asarray(masks.apply_expanded(jnp.asarray(y),
                                         jnp.asarray(gm), block))
    assert np.array_equal(y2, y)
    ident = np.asarray(masks.apply_expanded(jnp.asarray(x),
                                            jnp.ones((rows, g),
                                                     np.float32), block))
    assert np.array_equal(ident, x)


@settings(max_examples=200)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=3), _G, _BLOCK)
def test_mask_overhead_bytes_bit_packs_per_row(batch, g, block):
    """One bit per group per row, byte-rounded — and the stash cost for
    an (..., N) tensor never depends on the block size beyond G."""
    shape = tuple(batch) + (g * block,)
    rows = int(np.prod(batch))
    b = masks.mask_overhead_bytes(shape, block)
    assert b == rows * ((g + 7) // 8)
    # doubling the batch doubles the cost; eight groups fit one byte
    assert masks.mask_overhead_bytes((2,) + shape, block) == 2 * b
    assert masks.mask_overhead_bytes((8 * block,), block) == 1
