"""Pipeline parallelism: shard_map+ppermute GPipe vs sequential reference.
Runs in a subprocess with 4 host devices (the main test process must keep
the default 1-device platform)."""
import os
import subprocess
import sys
import textwrap

import numpy as np

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.compat import make_mesh
    from repro.parallel.pipeline import pipeline_forward, sequential_reference

    mesh = make_mesh((4,), ("pipe",))
    n_stages, n_micro, bm, d = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (n_stages, d, d)) * 0.2,
              "b": jnp.zeros((n_stages, d))}
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, bm, d))

    def stage(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    out = pipeline_forward(stage, params, x, mesh)
    want = sequential_reference(stage, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
""")


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
