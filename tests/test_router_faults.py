"""Fault-tolerant serving: health state machine, deterministic failover,
deadlines/retry budgets, and the eviction path (ISSUE 8).

The paper's determinism property is the load-bearing wall here: greedy
decode under per-row DRS selection is bit-identical to a solo run
regardless of lane or co-residents (pinned since PR 1), so a request
replayed from its prompt on a healthy replica after its replica died
must produce the SAME stream — every failover test below pins merged
streams bitwise against an undisturbed single-engine reference.
"""
import numpy as np
import pytest

from harness import (CHUNK_AXIS, assert_streams_equal, engine_spec,
                     make_engine_parts, mixed_traffic, run_and_collect)
from repro.runtime.fault_tolerance import (InjectedFault, ReplicaFault,
                                           ServingFaultInjector)
from repro.serving.router import (FaultToleranceConfig, Router,
                                  as_ft_config)
from repro.serving.scheduler import EngineAborted, Request, ServingEngine
from repro.serving.workload import run_workload, warmup_router


@pytest.fixture(scope="module")
def parts():
    return make_engine_parts()


@pytest.fixture(scope="module")
def ref_streams(parts):
    """Undisturbed single-engine reference streams for mixed_traffic."""
    cfg, params, dsg = parts
    return run_and_collect(engine_spec(cfg, params, dsg),
                           mixed_traffic(cfg))


def _router(parts, **kw):
    cfg, params, dsg = parts
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prompt_bucket", 32)
    return Router(cfg, params, dsg, **kw)


def _streams(done):
    return {u: list(r.output) for u, r in done.items()}


# -- failover determinism ----------------------------------------------------

@pytest.mark.parametrize("exec_mode", ["sequential", "threaded"])
def test_kill_failover_streams_bitwise_equal(parts, ref_streams,
                                             exec_mode):
    """Replica 1 killed mid-decode, zero restarts: it stays DEAD, its
    requests replay on survivors, and the merged streams are bitwise
    equal to the healthy run."""
    cfg = parts[0]
    inj = ServingFaultInjector([ReplicaFault(replica=1, step=3)])
    router = _router(parts, n_replicas=3, policy="round_robin",
                     exec_mode=exec_mode,
                     fault_tolerance=FaultToleranceConfig(
                         max_replica_restarts=0, max_retries=3))
    inj.attach(router.engines)
    for r in mixed_traffic(cfg):
        router.submit(r)
    try:
        done = router.run(max_steps=8000)
    finally:
        router.close()
    assert inj.log == [{"replica": 1, "step": 3, "kind": "kill"}]
    assert router.health[1].state == "dead"
    assert all(r.status == "ok" for r in done.values())
    assert any(r.retries > 0 for r in done.values())
    assert_streams_equal(ref_streams, _streams(done), exec_mode)


def test_poison_failover_discards_partial_output(parts, ref_streams):
    """A poison fault corrupts the victim lanes' last emitted token
    before raising — bitwise stream equality therefore proves failover
    replays from the prompt instead of resuming the tainted partial."""
    cfg = parts[0]
    inj = ServingFaultInjector(
        [ReplicaFault(replica=1, step=3, kind="poison")])
    router = _router(parts, n_replicas=3, policy="round_robin",
                     fault_tolerance=True)
    inj.attach(router.engines)
    for r in mixed_traffic(cfg):
        router.submit(r)
    done = router.run(max_steps=8000)
    assert router.health[1].restarts == 1     # default budget: restarted
    assert router.health[1].state == "healthy"
    assert_streams_equal(ref_streams, _streams(done), "poison")


def test_restarted_replica_serves_again(parts, ref_streams):
    """Within the restart budget the replica returns to HEALTHY and the
    policy routes to it again."""
    cfg = parts[0]
    inj = ServingFaultInjector([ReplicaFault(replica=0, step=2)])
    router = _router(parts, n_replicas=2, policy="round_robin",
                     fault_tolerance=FaultToleranceConfig(
                         max_replica_restarts=1))
    inj.attach(router.engines)
    for r in mixed_traffic(cfg):
        router.submit(r)
    done = router.run(max_steps=8000)
    assert router.health[0].state == "healthy"
    assert router.health[0].restarts == 1
    assert [ev[:2] for ev in router.health[0].events] == [
        ("healthy", "healthy")]            # restart logs a transition
    assert_streams_equal(ref_streams, _streams(done), "restart")


def test_threaded_stall_timeout_contains_straggler(parts, ref_streams):
    """A delayed worker (injected 0.9s sleep) trips stall_timeout_s:
    SUSPECT -> abort at the next step boundary -> restart, with streams
    still bitwise equal.  Healthy replicas are never falsely suspected
    (the idle->busy progress stamp)."""
    cfg = parts[0]
    inj = ServingFaultInjector(
        [ReplicaFault(replica=1, step=2, kind="delay", delay_s=0.9)])
    router = _router(parts, n_replicas=2, policy="round_robin",
                     exec_mode="threaded",
                     fault_tolerance=FaultToleranceConfig(
                         max_replica_restarts=1, stall_timeout_s=0.2))
    warmup_router(router, cfg.vocab)     # no compiles inside the window
    inj.attach(router.engines)
    for r in mixed_traffic(cfg):
        router.submit(r)
    try:
        done = router.run(max_steps=16000)
    finally:
        router.close()
    assert [h.restarts for h in router.health] == [0, 1]
    states = [ev[1] for ev in router.health[1].events]
    assert states == ["suspect", "healthy"]
    assert_streams_equal(ref_streams, _streams(done), "stall")


# -- graceful degradation ----------------------------------------------------

def test_all_replicas_dead_fails_requests_without_hanging(parts):
    cfg = parts[0]
    inj = ServingFaultInjector([ReplicaFault(replica=0, step=1),
                                ReplicaFault(replica=1, step=1)])
    router = _router(parts, n_replicas=2, policy="least_queue",
                     fault_tolerance=FaultToleranceConfig(
                         max_replica_restarts=0))
    inj.attach(router.engines)
    reqs = mixed_traffic(cfg)
    for r in reqs:
        router.submit(r)
    done = router.run(max_steps=400)       # returns — does not hang
    assert set(done) == {r.uid for r in reqs}
    assert all(h.state == "dead" for h in router.health)
    assert any(r.status == "failed" for r in done.values())
    assert all(r.status in ("ok", "failed") for r in done.values())
    assert all(r.finished > 0 for r in done.values())


@pytest.mark.parametrize("exec_mode", ["sequential", "threaded"])
def test_deadline_expiry_surfaces_timed_out(parts, exec_mode):
    """A queued request whose deadline passes while a long request holds
    the only lane finishes with status timed_out instead of hanging
    drain() — the acceptance-criteria case."""
    cfg, params, dsg = parts
    router = _router(parts, n_replicas=1, policy="least_pages", n_slots=1,
                     exec_mode=exec_mode, fault_tolerance=True)
    rng = np.random.default_rng(3)
    long_req = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                       max_new=30)
    late = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 8,
                                              dtype=np.int32),
                   max_new=4, deadline_s=1e-4)
    router.submit(long_req)
    router.submit(late)
    try:
        done = router.drain(max_steps=4000)
    finally:
        router.close()
    assert done[0].status == "ok" and len(done[0].output) == 30
    assert done[1].status == "timed_out" and done[1].output == []
    assert ("timed_out" in status for _, status, _ in router.fail_log)


def test_retry_budget_exhaustion_fails_request(parts):
    """A request that can never be admitted (reservation larger than the
    paged pool) keeps crashing its replica; once retries exceed
    max_retries it fails explicitly instead of looping forever."""
    cfg, params, dsg = parts
    router = _router(parts, n_replicas=1, policy="round_robin",
                     cache_backend="paged", page_size=8, cache_tokens=16,
                     fault_tolerance=FaultToleranceConfig(
                         max_replica_restarts=5, max_retries=1))
    rng = np.random.default_rng(5)
    router.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 24,
                                                     dtype=np.int32),
                          max_new=30))
    done = router.run(max_steps=400)
    assert done[0].status == "failed"
    assert done[0].retries == 2            # initial + 1 retry, then fail
    assert router.health[0].state == "healthy"   # restarts not exhausted


def test_fault_tolerance_off_keeps_fail_fast(parts):
    """Without opting in, an engine stall still raises (the historical
    contract) and str() carries the original message."""
    cfg, params, dsg = parts
    router = _router(parts, n_replicas=1, policy="round_robin",
                     cache_backend="paged", page_size=8, cache_tokens=16)
    rng = np.random.default_rng(5)
    router.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 24,
                                                     dtype=np.int32),
                          max_new=30))
    with pytest.raises(RuntimeError, match="engine stalled"):
        router.run(max_steps=400)


# -- health machine + policies ----------------------------------------------

def test_policies_skip_unhealthy_replicas(parts):
    router = _router(parts, n_replicas=3, policy="round_robin",
                     fault_tolerance=True)
    router._transition(1, "dead", "test")
    req = mixed_traffic(parts[0], n=1)[0]
    assert not router.routable(1)
    picks = [router.policy.select(router, req) for _ in range(4)]
    assert picks == [0, 2, 0, 2]           # cadence over the survivors
    router._transition(0, "suspect", "test")
    assert router.policy.select(router, req) == 2
    router._transition(2, "dead", "test")
    assert router.policy.select(router, req) is None


def test_ft_config_validation():
    assert as_ft_config(None) is None
    assert as_ft_config(True) == FaultToleranceConfig()
    assert as_ft_config({"max_retries": 5}).max_retries == 5
    cfg = FaultToleranceConfig(max_replica_restarts=3)
    assert as_ft_config(cfg) is cfg
    with pytest.raises(ValueError):
        as_ft_config("yes")
    with pytest.raises(ValueError):
        FaultToleranceConfig(max_replica_restarts=-1)
    with pytest.raises(ValueError):
        FaultToleranceConfig(max_retries=-1)
    with pytest.raises(ValueError):
        FaultToleranceConfig(stall_timeout_s=0.0)


def test_reset_health_revives_replicas(parts):
    inj = ServingFaultInjector([ReplicaFault(replica=0, step=1)])
    router = _router(parts, n_replicas=2, policy="least_queue",
                     fault_tolerance=FaultToleranceConfig(
                         max_replica_restarts=0))
    inj.attach(router.engines)
    for r in mixed_traffic(parts[0]):
        router.submit(r)
    router.run(max_steps=400)
    assert router.health[0].state == "dead"
    router.reset_health()
    assert all(h.state == "healthy" and h.restarts == 0
               for h in router.health)
    assert not router.failed and not router.fail_log
    # revived: serves a fresh batch end to end
    inj.reset()
    inj.detach(router.engines)
    for r in mixed_traffic(parts[0], seed=31):
        router.submit(r)
    done = router.run(max_steps=400)
    assert all(r.status == "ok" for r in done.values())


# -- engine eviction path ----------------------------------------------------

def test_evict_request_releases_pages(parts):
    cfg, params, dsg = parts
    eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                        prompt_bucket=32, cache_backend="paged",
                        page_size=8, cache_tokens=128)
    pages0 = eng.free_pages()
    rng = np.random.default_rng(7)
    req = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 8,
                                             dtype=np.int32), max_new=20)
    eng.submit(req)
    eng.step()
    assert eng.free_pages() < pages0       # reservation held
    assert eng.evict_request(0) is req
    assert eng.free_pages() == pages0      # reservation fully returned
    assert eng.free_slots() == eng.n_slots
    assert eng.evict_request(0) is None    # already gone
    assert 0 not in eng.done               # evicted, not retired


def test_engine_reset_reclaims_in_admission_order(parts):
    cfg, params, dsg = parts
    eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                        prompt_bucket=32)
    rng = np.random.default_rng(9)
    reqs = [Request(uid=u, prompt=rng.integers(0, cfg.vocab, 8,
                                               dtype=np.int32),
                    max_new=20) for u in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()                             # admits 2, queues 1
    assert eng.busy_slots() == 2 and eng.queue_depth() == 1
    eng.done[99] = Request(uid=99, prompt=np.zeros(1, np.int32))
    reclaimed = eng.reset()
    assert [r.uid for r in reclaimed] == [0, 1, 2]
    assert eng.queue_depth() == 0 and eng.free_slots() == 2
    assert 99 in eng.done                  # done preserved across reset


def test_engine_abort_raises_at_step_boundary(parts):
    cfg, params, dsg = parts
    eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                        prompt_bucket=32)
    rng = np.random.default_rng(11)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                       max_new=6))
    eng.step()
    eng.abort = True
    with pytest.raises(EngineAborted):
        eng.step()
    assert not eng.abort                   # cleared by the raise
    eng.step()                             # next boundary proceeds


def test_injector_fires_each_fault_exactly_once(parts):
    cfg, params, dsg = parts
    eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                        prompt_bucket=32)
    inj = ServingFaultInjector([ReplicaFault(replica=0, step=0)])
    inj.attach([eng])
    rng = np.random.default_rng(13)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                       max_new=4))
    with pytest.raises(InjectedFault):
        eng.step()
    done = eng.run(max_steps=100)          # same steps: never re-fires
    assert done[0].status == "ok"
    assert len(inj.log) == 1


# -- shutdown ----------------------------------------------------------------

def test_threaded_close_idempotent_and_restartable(parts):
    cfg = parts[0]
    router = _router(parts, n_replicas=2, policy="least_queue",
                     exec_mode="threaded")
    for r in mixed_traffic(cfg):
        router.submit(r)
    done = router.run(max_steps=8000)
    assert len(done) == 6
    router.close()
    router.close()                         # second close: clean no-op
    for r in mixed_traffic(cfg, seed=41):
        router.submit(r)
    done2 = router.run(max_steps=8000)     # workers restaff after close
    assert len(done2) == 6
    router.close()


# -- workload integration ----------------------------------------------------

def test_run_workload_chaos_stats(parts):
    """run_workload(faults=...) auto-enables fault tolerance, forces the
    Router path, and reports the chaos counters."""
    cfg, params, dsg = parts
    reqs = mixed_traffic(cfg)
    stats = run_workload(
        cfg, params, dsg, reqs, n_slots=2, max_seq=64, prompt_bucket=32,
        replicas=2, route_policy="round_robin",
        faults=[ReplicaFault(replica=1, step=2)])
    assert stats["faults_fired"] == 1
    assert stats["completed_ok"] == len(reqs)
    assert stats["failed"] == 0 and stats["timed_out"] == 0
    assert stats["retries"] > 0
    assert stats["replica_health"] == ["healthy", "healthy"]


@pytest.mark.parametrize("chunk", CHUNK_AXIS)
def test_kill_failover_invariant_to_decode_chunk(parts, ref_streams, chunk):
    """Chaos kill under the fused chunk loop (harness faults= path):
    the victim's requests replay on survivors and the merged streams
    match the healthy unchunked reference for every chunk size."""
    cfg = parts[0]
    streams = run_and_collect(
        engine_spec(*parts, decode_chunk=chunk, n_replicas=3,
                    policy="round_robin",
                    fault_tolerance=FaultToleranceConfig(
                        max_replica_restarts=0, max_retries=3)),
        mixed_traffic(cfg), max_steps=8000,
        faults=[ReplicaFault(replica=1, step=3)])
    assert_streams_equal(ref_streams, streams, f"chaos decode_chunk={chunk}")
