"""Deep coverage for the fault-tolerance substrate (ISSUE 8 satellite):
straggler detection on injected delays, exactly-once fault injection,
bit-exact restart-from-checkpoint, the serving chaos injector's unit
behavior, and elastic re-planning invariants.

test_substrate.py holds the original smoke coverage; this file pins the
contracts the serving failover path (tests/test_router_faults.py) and
bench_router_faults.py lean on.
"""
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.runtime.elastic import ElasticPlan, plan_after_loss
from repro.runtime.fault_tolerance import (FAULT_KINDS, POISON_TOKEN,
                                           FaultInjector, InjectedFault,
                                           ReplicaFault,
                                           ServingFaultInjector,
                                           StragglerMonitor,
                                           run_with_restarts)


# -- straggler monitor -------------------------------------------------------

def test_straggler_monitor_flags_injected_delays():
    mon = StragglerMonitor(window=16, factor=1.5)
    delayed = {12, 17}
    for step in range(20):
        seconds = 0.10 if step not in delayed else 0.35
        flagged = mon.record(step, seconds)
        assert flagged == (step in delayed)
    assert [f[0] for f in mon.flagged] == sorted(delayed)
    for step, seconds, median in mon.flagged:
        assert seconds > 1.5 * median


def test_straggler_monitor_needs_history():
    """No flags until the rolling median has >= 8 samples — a cold
    monitor must not flag the first jit-compile step."""
    mon = StragglerMonitor()
    assert not mon.record(0, 100.0)
    for step in range(1, 8):
        mon.record(step, 0.1)
    assert mon.record(8, 100.0)        # 9th sample: median established


def test_straggler_monitor_rolling_window():
    """The median tracks the WINDOW, not all history: after a regime
    change to uniformly slower steps, the old fast median ages out and
    the slower steps stop being flagged."""
    mon = StragglerMonitor(window=8, factor=1.5)
    for step in range(8):
        mon.record(step, 0.1)
    assert mon.record(8, 0.3)          # slow vs the fast window
    for step in range(9, 17):
        mon.record(step, 0.3)          # new normal fills the window
    assert not mon.record(17, 0.3)


# -- training-side fault injection -------------------------------------------

def test_fault_injector_fires_exactly_once_per_step():
    inj = FaultInjector(fail_at=(3,))
    with pytest.raises(RuntimeError, match="injected failure at step 3"):
        inj.maybe_fail(3)
    inj.maybe_fail(3)                  # replay after restore: no re-fire
    inj.maybe_fail(4)


def test_run_with_restarts_restore_is_bit_exact(tmp_path):
    """A run that crashes at step 30 and restores from the step-20
    checkpoint replays 20..29 and lands bit-identical to a fault-free
    run — the make_batch(step) purity contract."""
    def step_fn(state, batch):
        s = state["x"] * 1.000001 + batch
        return {"x": s}, {"loss": float(np.sum(s))}

    def make_batch(step):
        return np.full(4, step, dtype=np.float64)

    clean, _ = run_with_restarts(
        step_fn=step_fn, state={"x": np.zeros(4)}, make_batch=make_batch,
        ckpt=None, total_steps=40)

    mgr = CheckpointManager(str(tmp_path), keep=3)
    # leg 1 writes the step-20 checkpoint and waits for the async flush
    mid, _ = run_with_restarts(
        step_fn=step_fn, state={"x": np.zeros(4)}, make_batch=make_batch,
        ckpt=mgr, total_steps=20, ckpt_every=20)
    # leg 2 crashes at step 30, restores step 20, replays 20..29
    faulty, hist = run_with_restarts(
        step_fn=step_fn, state=mid, make_batch=make_batch,
        ckpt=mgr, total_steps=40, start_step=20, ckpt_every=1000,
        injector=FaultInjector(fail_at=(30,)))
    np.testing.assert_array_equal(clean["x"], faulty["x"])
    # steps 20..29 ran twice (before the crash, then replayed)
    assert [h["step"] for h in hist].count(25) == 2


def test_run_with_restarts_exhausts_retries():
    inj = FaultInjector(fail_at=(2,), exc=OSError)
    calls = []

    def bad(state, batch):
        calls.append(batch)
        raise ValueError("persistent")

    with pytest.raises(ValueError, match="persistent"):
        run_with_restarts(step_fn=bad, state={}, make_batch=lambda s: s,
                          ckpt=None, total_steps=4, max_retries=2)
    assert len(calls) == 3             # initial + 2 retries, then raise
    with pytest.raises(OSError):       # injector exc type respected
        run_with_restarts(step_fn=lambda s, b: (s, {}), state={},
                          make_batch=lambda s: s, ckpt=None,
                          total_steps=4, max_retries=0, injector=inj)


# -- serving chaos injector ---------------------------------------------------

def _fake_engine(replica=0, steps=0, outputs=()):
    slots = [SimpleNamespace(req=SimpleNamespace(output=list(o))
                             if o is not None else None)
             for o in outputs]
    # mirror the ServingEngine fields on_step()/attach() touch
    return SimpleNamespace(replica_index=replica, steps=steps,
                           slots=slots, fault_injector=None)


def test_replica_fault_validation():
    assert FAULT_KINDS == ("kill", "delay", "poison")
    with pytest.raises(ValueError, match="unknown fault kind"):
        ReplicaFault(replica=0, step=0, kind="explode")
    with pytest.raises(ValueError, match=">= 0"):
        ReplicaFault(replica=-1, step=0)
    with pytest.raises(ValueError, match=">= 0"):
        ReplicaFault(replica=0, step=-2)
    # tuple coercion in the injector ctor
    inj = ServingFaultInjector([(1, 4), (0, 2, "delay", 0.01)])
    assert inj.faults[0] == ReplicaFault(replica=1, step=4)
    assert inj.faults[1].kind == "delay"


def test_serving_injector_keys_on_replica_and_step():
    inj = ServingFaultInjector([ReplicaFault(replica=1, step=3)])
    inj.on_step(_fake_engine(replica=0, steps=3))   # wrong replica
    inj.on_step(_fake_engine(replica=1, steps=2))   # wrong step
    assert inj.log == []
    with pytest.raises(InjectedFault, match="replica 1 step 3"):
        inj.on_step(_fake_engine(replica=1, steps=3))
    assert inj.log == [{"replica": 1, "step": 3, "kind": "kill"}]
    # exactly once: the restarted replica passes step 3 again unharmed
    inj.on_step(_fake_engine(replica=1, steps=3))
    assert len(inj.log) == 1
    inj.reset()                        # re-armed for a benchmark repeat
    with pytest.raises(InjectedFault):
        inj.on_step(_fake_engine(replica=1, steps=3))


def test_serving_injector_delay_sleeps_without_raising():
    inj = ServingFaultInjector(
        [ReplicaFault(replica=0, step=1, kind="delay", delay_s=0.05)])
    eng = _fake_engine(steps=1)
    t0 = time.perf_counter()
    inj.on_step(eng)                   # no raise
    assert time.perf_counter() - t0 >= 0.05
    assert inj.log[0]["kind"] == "delay"


def test_serving_injector_poison_corrupts_resident_lanes():
    inj = ServingFaultInjector(
        [ReplicaFault(replica=0, step=2, kind="poison")])
    eng = _fake_engine(steps=2, outputs=([5, 6], None, []))
    with pytest.raises(InjectedFault, match="poison"):
        inj.on_step(eng)
    assert eng.slots[0].req.output == [5, POISON_TOKEN]
    assert eng.slots[2].req.output == []       # nothing emitted yet


def test_serving_injector_attach_detach():
    inj = ServingFaultInjector([])
    engines = [_fake_engine(), _fake_engine()]
    inj.attach(engines)
    assert [e.replica_index for e in engines] == [0, 1]
    assert all(e.fault_injector is inj for e in engines)
    other = ServingFaultInjector([])
    other.attach([engines[1]])
    inj.detach(engines)                # only detaches its own hookups
    assert engines[0].fault_injector is None
    assert engines[1].fault_injector is other


# -- elastic re-planning ------------------------------------------------------

@pytest.mark.parametrize("available,model", [
    (496, 16), (300, 16), (17, 16), (64, 8), (1, 1), (1023, 4)])
def test_plan_after_loss_invariants(available, model):
    p = plan_after_loss(available, model=model)
    assert p.model == model                      # model axis intact
    assert p.data & (p.data - 1) == 0            # power-of-two data axis
    assert p.n_devices == p.data * model
    assert p.n_devices + p.dropped == available  # device accounting
    assert p.data * 2 * model > available        # largest such pow2
    assert 0.0 < p.scale <= 1.0


def test_plan_after_loss_raises_below_model_axis():
    with pytest.raises(RuntimeError, match="cannot keep model=16"):
        plan_after_loss(15, model=16)


def test_plan_scale_reflects_dropped_fraction():
    p = ElasticPlan(n_devices=256, data=16, model=16, dropped=256)
    assert p.scale == pytest.approx(0.5)
