"""Hypothesis property tests for the serving substrate: the paged
backend's BlockAllocator (random alloc/free interleavings never
double-assign a physical page and conserve the free-list count) and the
scheduler's static-shape helpers live_page_bound / live_page_buckets /
bucket_sizes (monotone, pow2-bucketed, always covering the write
position).  These are host-side pure functions — no jit, no device —
so hundreds of examples run in milliseconds."""
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.serving.kv_cache import BlockAllocator, OutOfPages
from repro.serving.scheduler import (DEFAULT_BUCKETS, bucket_sizes,
                                     live_page_bound, live_page_buckets)


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------

@settings(max_examples=200)
@given(st.integers(1, 24), st.integers(0, 3),
       st.lists(st.tuples(st.booleans(), st.integers(0, 10)), max_size=50))
def test_allocator_never_double_assigns_and_conserves(alloc_pages,
                                                      reserved, ops):
    """Any interleaving of allocs and frees: handed-out pages are unique,
    never below `reserved`, disjoint from everything currently live, and
    free_pages + live == allocatable at every step.  Requests beyond
    capacity raise OutOfPages and leave the state untouched."""
    n_pages = reserved + alloc_pages
    a = BlockAllocator(n_pages, reserved=reserved)
    live = []                                 # pages we hold, in FIFO order
    for is_alloc, k in ops:
        if is_alloc:
            if k > a.free_pages:
                before = (a.free_pages, sorted(live))
                with pytest.raises(OutOfPages):
                    a.alloc(k)
                assert (a.free_pages, sorted(live)) == before
            else:
                got = a.alloc(k)
                assert len(got) == len(set(got)) == k
                assert all(reserved <= p < n_pages for p in got)
                assert not set(got) & set(live)   # never double-assigned
                live.extend(got)
        elif live:
            take = live[:min(k, len(live))]
            del live[:len(take)]
            if take:
                a.free(take)
        # conservation: every allocatable page is free or held, never both
        assert a.free_pages + len(live) == alloc_pages
        assert len(set(live)) == len(live)


@settings(max_examples=100)
@given(st.integers(1, 16), st.integers(1, 8))
def test_allocator_rejects_double_free_and_foreign(alloc_pages, k):
    a = BlockAllocator(alloc_pages + 1, reserved=1)
    got = a.alloc(min(k, alloc_pages))
    a.free(got)
    with pytest.raises(ValueError):           # double free
        a.free(got[:1])
    with pytest.raises(ValueError):           # reserved id never allocated
        a.free([0])
    assert a.free_pages == alloc_pages


# ---------------------------------------------------------------------------
# BlockAllocator: share / register / free interleavings (prefix sharing)
# ---------------------------------------------------------------------------

# ops: 0=alloc+register, 1=share a random indexed page, 2=free one of our
# refs, 3=free ALL refs on a random held page (retire-style release)
_SHARE_OPS = st.lists(st.tuples(st.integers(0, 3), st.integers(0, 9)),
                      max_size=60)


@settings(max_examples=200)
@given(st.integers(2, 16), _SHARE_OPS)
def test_share_cow_free_interleavings_conserve(alloc_pages, ops):
    """Random share/free interleavings over an indexed allocator: a page
    is free-listed exactly when its refcount hits zero (never while a
    holder remains), total pages are conserved (free + live ==
    allocatable), the index never points at a freed page, and releasing
    a ref twice past zero raises instead of double-freeing."""
    a = BlockAllocator(alloc_pages + 1, reserved=1)
    refs = {}                                 # page -> refs WE hold
    key_of = {}                               # page -> registered key
    n_keys = 0
    for op, pick in ops:
        if op == 0 and a.free_pages:
            (p,) = a.alloc(1)
            assert p not in refs              # free list never lies
            refs[p] = 1
            k = b"key%d" % n_keys
            n_keys += 1
            a.register(k, p)
            key_of[p] = k
        elif op == 1 and refs:
            p = sorted(refs)[pick % len(refs)]
            rc = a.share(p)
            refs[p] += 1
            assert rc == refs[p] == a.refcount(p)
        elif op == 2 and refs:
            p = sorted(refs)[pick % len(refs)]
            refs[p] -= 1
            a.free([p])
            if refs[p] == 0:
                del refs[p]
                assert a.refcount(p) == 0
                assert a.lookup(key_of.pop(p)) is None   # index died with it
                with pytest.raises(ValueError):          # release past zero
                    a.free([p])
                with pytest.raises(ValueError):          # can't share a corpse
                    a.share(p)
        elif op == 3 and refs:
            p = sorted(refs)[pick % len(refs)]
            a.free([p] * refs.pop(p))
            assert a.refcount(p) == 0
            assert a.lookup(key_of.pop(p)) is None
        # invariants, every step:
        assert a.free_pages + len(refs) == alloc_pages   # conservation
        assert a.live_pages == len(refs)
        for p, k in key_of.items():
            assert a.lookup(k) == p                      # index is live-only
        assert a.index_size == len(key_of)


@settings(max_examples=150)
@given(st.integers(1, 8), st.integers(1, 5))
def test_register_is_first_writer_wins_and_live_only(alloc_pages, extra):
    """register() refuses freed pages, keeps the first binding on key
    collision, and lookup of a never-registered key is None."""
    a = BlockAllocator(alloc_pages + 1, reserved=1)
    pages = a.alloc(alloc_pages)
    a.register(b"k", pages[0])
    for p in pages[:extra]:
        a.register(b"k", p)                   # later bindings ignored
    assert a.lookup(b"k") == pages[0]
    assert a.lookup(b"nope") is None
    a.free(pages)
    with pytest.raises(ValueError):
        a.register(b"k2", pages[0])
    assert a.index_size == 0


# ---------------------------------------------------------------------------
# live_page_bound / live_page_buckets
# ---------------------------------------------------------------------------

_PAGE_SIZES = st.sampled_from([4, 8, 16, 32])


@settings(max_examples=200)
@given(_PAGE_SIZES, st.integers(1, 64), st.data())
def test_live_page_bound_covers_and_buckets(ps, max_pages, data):
    """The static walk bound always covers the deepest write position,
    never exceeds the page-table width, and lands in the pre-compiled
    pow2 bucket set (so warm_decode has compiled it)."""
    pos = data.draw(st.integers(0, max_pages * ps - 1))
    b = live_page_bound(pos, ps, max_pages)
    assert 1 <= b <= max_pages
    assert b * ps > pos                       # bound covers the write
    assert b in live_page_buckets(max_pages)  # warm_decode compiled it
    assert b == max_pages or (b & (b - 1)) == 0   # pow2 unless capped


@settings(max_examples=200)
@given(_PAGE_SIZES, st.integers(1, 64), st.data())
def test_live_page_bound_monotone(ps, max_pages, data):
    """Deeper batches can only widen the walk: the bound is monotone in
    max_pos, so a bound computed for the deepest lane covers every lane."""
    hi = max_pages * ps - 1
    p1 = data.draw(st.integers(0, hi))
    p2 = data.draw(st.integers(p1, hi))
    assert live_page_bound(p1, ps, max_pages) \
        <= live_page_bound(p2, ps, max_pages)


@settings(max_examples=100)
@given(st.integers(1, 64))
def test_live_page_buckets_membership(max_pages):
    buckets = live_page_buckets(max_pages)
    assert buckets == tuple(sorted(set(buckets)))       # sorted, unique
    assert buckets[-1] == max_pages                     # cap is reachable
    for b in buckets:
        assert 1 <= b <= max_pages
        assert b == max_pages or (b & (b - 1)) == 0


# ---------------------------------------------------------------------------
# bucket_sizes
# ---------------------------------------------------------------------------

@settings(max_examples=200)
@given(st.integers(1, 512), st.integers(2, 512))
def test_bucket_sizes_capped_sorted_covering(prompt_bucket, max_seq):
    """Prompt buckets are sorted, unique, never exceed the admission cap
    min(prompt_bucket, max_seq - 1) (a full-cache prompt would leave no
    decode headroom), and the largest bucket IS the cap whenever the cap
    is within the default series — so every admissible prompt has a
    bucket that holds it."""
    cap = min(prompt_bucket, max_seq - 1)
    bs = bucket_sizes(prompt_bucket, max_seq)
    assert bs == tuple(sorted(set(bs)))
    assert all(1 <= b <= cap for b in bs)
    assert bs[-1] == min(cap, max(DEFAULT_BUCKETS))
    # monotone in the cap: shrinking prompt_bucket never widens a bucket
    smaller = bucket_sizes(max(prompt_bucket // 2, 1), max_seq)
    assert smaller[-1] <= bs[-1]
