"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies exactly as Mosaic would)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels import drs_search, dsg_ffn, ops, ref

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _mk(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,k,bm", [(64, 128, 64, 32), (256, 320, 128, 128),
                                      (128, 512, 256, 64)])
def test_drs_project(dtype, m, d, k, bm):
    kx, kr = jax.random.split(jax.random.PRNGKey(0))
    x = _mk(kx, (m, d), dtype)
    r = _mk(kr, (k, d), dtype) / np.sqrt(k)
    out = drs_search.drs_project(x, r, bm=bm, interpret=True)
    want = ref.drs_project_ref(x.astype(jnp.float32),
                               r.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,f,block,bm,bf", [
    (64, 64, 256, 32, 32, 64), (128, 128, 512, 64, 64, 128),
    (32, 64, 1024, 128, 32, 256)])
def test_drs_scores(dtype, m, k, f, block, bm, bf):
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    fx = _mk(kx, (m, k), dtype)
    fw = _mk(kw, (k, f), dtype)
    out = drs_search.drs_scores(fx, fw, block=block, bm=bm, bf=bf,
                                interpret=True)
    want = ref.drs_scores_ref(fx.astype(jnp.float32),
                              fw.astype(jnp.float32), block)
    np.testing.assert_allclose(np.asarray(out), want,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,f,block,bm,bf", [
    (64, 96, 256, 32, 32, 64), (128, 128, 512, 64, 64, 128),
    (64, 256, 512, 128, 64, 128)])
def test_dsg_ffn(dtype, m, d, f, block, bm, bf):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = _mk(ks[0], (m, d), dtype)
    wg = _mk(ks[1], (d, f), dtype) / np.sqrt(d)
    wu = _mk(ks[2], (d, f), dtype) / np.sqrt(d)
    wd = _mk(ks[3], (f, d), dtype) / np.sqrt(f)
    mask = (jax.random.uniform(ks[4], (m, f // block)) > 0.4).astype(
        jnp.float32)
    out = dsg_ffn.dsg_ffn(x, wg, wu, wd, mask, block=block, bm=bm, bf=bf,
                          interpret=True)
    want = ref.dsg_ffn_ref(x.astype(jnp.float32), wg.astype(jnp.float32),
                           wu.astype(jnp.float32), wd.astype(jnp.float32),
                           mask, block)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32), want, **tol)


def test_dsg_ffn_all_masked_is_zero():
    m, d, f, block = 32, 64, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = _mk(ks[0], (m, d), jnp.float32)
    wg = _mk(ks[1], (d, f), jnp.float32)
    wu = _mk(ks[2], (d, f), jnp.float32)
    wd = _mk(ks[3], (f, d), jnp.float32)
    mask = jnp.zeros((m, f // block))
    out = dsg_ffn.dsg_ffn(x, wg, wu, wd, mask, block=block, bm=32, bf=32,
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       mt=st.integers(1, 4), ft=st.integers(1, 4),
       density=st.floats(0.0, 1.0))
def test_dsg_ffn_property(seed, mt, ft, density):
    """Property sweep: random tile counts and mask densities; kernel output
    must equal the oracle for every configuration."""
    block, bm, bf, d = 16, 16, 32, 48
    m, f = mt * bm, ft * bf
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _mk(ks[0], (m, d), jnp.float32)
    wg = _mk(ks[1], (d, f), jnp.float32) * 0.1
    wu = _mk(ks[2], (d, f), jnp.float32) * 0.1
    wd = _mk(ks[3], (f, d), jnp.float32) * 0.1
    mask = (jax.random.uniform(ks[4], (m, f // block)) < density).astype(
        jnp.float32)
    out = dsg_ffn.dsg_ffn(x, wg, wu, wd, mask, block=block, bm=bm, bf=bf,
                          interpret=True)
    want = ref.dsg_ffn_ref(x, wg, wu, wd, mask, block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_end_to_end_kernel_path_matches_jax_path():
    """ops.dsg_ffn_full (kernels) vs core.dsg_linear.swiglu_dsg_mask (jnp):
    same projection state -> identical selection -> allclose outputs."""
    from repro.core import dsg_linear as dl
    d, f, m, block = 128, 512, 64, 64
    cfg = dl.DSGConfig(enabled=True, gamma=0.5, block=block, eps=0.5)
    p = dl.init_swiglu(jax.random.PRNGKey(0), d, f)
    st_ = dl.init_dsg_state(jax.random.PRNGKey(1), d, f, cfg,
                            dl.search_weight(p))
    x = jax.random.normal(jax.random.PRNGKey(2), (m, d))
    y_jax = dl.swiglu_dsg_mask(p, x, st_, cfg)
    y_kernel = ops.dsg_ffn_full(x, p["w_gate"], p["w_up"], p["w_down"],
                                st_["r"], st_["fw"], gamma=0.5, block=block)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_jax),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,t,d,causal,bq,bk", [
    (4, 128, 128, 32, True, 32, 32),
    (2, 64, 192, 64, False, 32, 64),
    (2, 256, 256, 64, True, 128, 64),
])
def test_flash_attention(dtype, bh, s, t, d, causal, bq, bk):
    from repro.kernels import flash_attention as fa
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _mk(ks[0], (bh, s, d), dtype)
    k = _mk(ks[1], (bh, t, d), dtype)
    v = _mk(ks[2], (bh, t, d), dtype)
    out = fa.flash_attention(q, k, v, causal=causal, block_q=bq,
                             block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), **tol)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nq=st.integers(1, 4),
       nk=st.integers(1, 4), causal=st.booleans())
def test_flash_attention_property(seed, nq, nk, causal):
    from repro.kernels import flash_attention as fa
    bq = bk = 16
    d, bh = 16, 2
    s, t = nq * bq, nk * bk
    if causal and t < s:
        t = s
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _mk(ks[0], (bh, s, d), jnp.float32)
    k = _mk(ks[1], (bh, t, d), jnp.float32)
    v = _mk(ks[2], (bh, t, d), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=causal, block_q=bq,
                             block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
