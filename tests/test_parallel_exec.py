"""Replica executors (serving/parallel_exec.py).

The load-bearing invariant extends PR 4's: merged greedy token streams
keyed by request uid must be IDENTICAL across replica COUNTS (pinned by
test_router.py) and across EXECUTORS — how the replica group runs
(stepped in sequence, free-running worker threads, one vmapped device
step) decides only WHEN and WHERE a request decodes, never WHAT.  The
matrix here pins {sequential, threaded} x {dense, paged} x {1, 2, 3}
replicas bitwise against the bare-engine stream, plus the sharded
executor (with and without a `replicas` mesh axis).  On top of that:
`makespan_seconds()` must switch between the sequential executor's
MODELED number (max per-replica busy time) and the parallel executors'
MEASURED wall clock, worker exceptions must surface in the caller's
thread, and the threaded drive loop must detect the undispatchable-head
stall instead of hanging."""
import jax
import numpy as np
import pytest

from harness import (assert_streams_equal, engine_spec, make_engine_parts,
                     mixed_traffic, run_and_collect)
from repro.parallel.sharding import replica_mesh
from repro.serving.parallel_exec import (ReplicaProxy,
                                         SequentialExecutor, get_executor)
from repro.serving.router import Router
from repro.serving.scheduler import Request


@pytest.fixture(scope="module")
def engine_parts():
    return make_engine_parts()


_BACKEND_KW = {
    "dense": {},
    # worst-case lane reservation: min(bucket 32 + max_new 8, 64) = 40
    # tokens = 5 pages of 8; 80-token pools hold two lanes per replica
    "paged": {"cache_backend": "paged", "page_size": 8, "cache_tokens": 80},
}

# module-level memo: the bare-engine reference stream per backend,
# computed once and shared across the executor parametrizations
_baseline = {}


def _reference(engine_parts, backend):
    if backend not in _baseline:
        spec = engine_spec(*engine_parts, **_BACKEND_KW[backend])
        _baseline[backend] = run_and_collect(spec,
                                             mixed_traffic(spec["cfg"]))
    return _baseline[backend]


# ---------------------------------------------------------------------------
# guards / proxy plumbing (no engine runs — cheap)
# ---------------------------------------------------------------------------

def test_exec_mode_guards(engine_parts):
    cfg, params, dsg = engine_parts
    with pytest.raises(ValueError):
        Router(cfg, params, dsg, exec_mode="processes")
    with pytest.raises(ValueError):
        get_executor("processes", [])
    with pytest.raises(ValueError):          # mesh without a replicas axis
        get_executor("sharded", [], mesh=jax.sharding.Mesh(
            np.array(jax.devices()[:1]), axis_names=("data",)))
    router = Router(cfg, params, dsg, n_replicas=2, n_slots=2,
                    max_seq=64, exec_mode="threaded")
    with pytest.raises(RuntimeError):        # free-running: no lockstep tick
        router.step()
    router.close()


def test_replica_proxy_forwards(engine_parts):
    """Policies and stats code talk to executor-owned proxies; attribute
    reads AND writes must land on the underlying engine (bench_router's
    steady-state reset assigns counters through router.replicas)."""
    cfg, params, dsg = engine_parts
    router = Router(cfg, params, dsg, n_replicas=2, n_slots=3, max_seq=64)
    proxy = router.replicas[0]
    assert isinstance(proxy, ReplicaProxy)
    assert proxy.engine is router.engines[0]
    assert proxy.n_slots == 3 and proxy.free_slots() == 3
    proxy.steps = 7                          # write-through, not shadowing
    assert router.engines[0].steps == 7
    req = Request(uid=0, prompt=np.zeros(4, np.int32), max_new=2)
    proxy.submit(req)                        # routes through the executor
    assert router.engines[0].queue_depth() == 1


# ---------------------------------------------------------------------------
# executor invariance (the acceptance matrix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dense", "paged"])
@pytest.mark.parametrize("exec_mode", ["sequential", "threaded"])
def test_executor_invariance(engine_parts, backend, exec_mode):
    """Merged greedy token streams are bitwise identical to the
    bare-engine reference for 1, 2, and 3 replicas under every executor
    x backend combination: requests are dispatched whole and each
    replica is solo-deterministic, so execution strategy is invisible in
    the results."""
    ref = _reference(engine_parts, backend)
    for n in (1, 2, 3):
        spec = engine_spec(*engine_parts, n_replicas=n,
                           exec_mode=exec_mode, **_BACKEND_KW[backend])
        out, router = run_and_collect(spec, mixed_traffic(spec["cfg"]),
                                      max_steps=100_000,
                                      return_engine=True)
        assert_streams_equal(ref, out,
                             f"{backend}/{exec_mode}/{n} replicas")
        uids = [u for u, _ in router.dispatch_log]
        assert sorted(uids) == sorted(ref)
        router.close()


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_sharded_executor_streams(engine_parts, backend):
    """The vmapped group step must reproduce the bare-engine streams:
    stacking operands/caches along the replica axis and fusing N decode
    dispatches into one cannot change per-replica content."""
    ref = _reference(engine_parts, backend)
    spec = engine_spec(*engine_parts, n_replicas=2, exec_mode="sharded",
                       **_BACKEND_KW[backend])
    out = run_and_collect(spec, mixed_traffic(spec["cfg"]),
                          max_steps=100_000)
    assert_streams_equal(ref, out, f"sharded/{backend}/2 replicas")


@pytest.mark.skipif(jax.local_device_count() < 2,
                    reason="needs >= 2 devices for a replicas mesh")
def test_sharded_executor_on_replica_mesh(engine_parts):
    """With a `replicas` mesh axis the stacked group is laid out one
    replica per device (parallel.sharding.replica_mesh) — streams must
    still match the single-device reference bitwise."""
    ref = _reference(engine_parts, "dense")
    spec = engine_spec(*engine_parts, n_replicas=2, exec_mode="sharded")
    spec["mesh"] = replica_mesh(2)
    out = run_and_collect(spec, mixed_traffic(spec["cfg"]),
                          max_steps=100_000)
    assert_streams_equal(ref, out, "sharded/replicas-mesh/2")


# ---------------------------------------------------------------------------
# measured vs modeled makespan
# ---------------------------------------------------------------------------

def test_makespan_selection(engine_parts):
    """The sequential executor records per-replica busy time and
    `makespan_seconds()` MODELS the parallel wall clock from it (max);
    the threaded executor truly overlaps replicas, so the same method
    reports the MEASURED drive wall clock instead."""
    cfg = engine_parts[0]
    spec = engine_spec(*engine_parts, n_replicas=2)
    seq_out, seq = run_and_collect(spec, mixed_traffic(cfg),
                                   return_engine=True)
    assert isinstance(seq.executor, SequentialExecutor)
    assert not seq.executor.measured
    assert seq.makespan_seconds() == max(seq.busy_seconds)
    assert seq.makespan_seconds() > 0
    # the wall clock of serialized stepping covers BOTH replicas' work,
    # so the modeled (parallel) makespan must undercut it
    assert seq.makespan_seconds() <= seq.executor.wall_seconds

    spec = engine_spec(*engine_parts, n_replicas=2, exec_mode="threaded")
    thr_out, thr = run_and_collect(spec, mixed_traffic(cfg),
                                   return_engine=True)
    assert thr.executor.measured
    assert thr.makespan_seconds() == thr.executor.wall_seconds
    assert thr.makespan_seconds() > 0
    assert_streams_equal(seq_out, thr_out, "makespan test streams")
    thr.close()

    # reset_counters() zeroes the executor's timing for steady-state
    # measurement windows
    seq.reset_counters()
    assert seq.executor.wall_seconds == 0
    assert seq.busy_seconds == [0.0, 0.0]


# ---------------------------------------------------------------------------
# failure propagation from worker threads
# ---------------------------------------------------------------------------

def test_threaded_engine_stall_surfaces(engine_parts):
    """An engine whose paged pool cannot hold one request's reservation
    raises from its worker thread; the drive loop must re-raise in the
    caller's thread instead of hanging (round_robin dispatches
    unconditionally, so the stall happens inside the engine)."""
    cfg, params, dsg = engine_parts
    router = Router(cfg, params, dsg, n_replicas=2, policy="round_robin",
                    exec_mode="threaded", n_slots=2, max_seq=64,
                    prompt_bucket=32, cache_backend="paged", page_size=8,
                    cache_tokens=16)
    router.submit(Request(uid=0, prompt=np.zeros(30, np.int32),
                          max_new=16))
    with pytest.raises(RuntimeError, match="stalled"):
        router.run(max_steps=2_000)
    router.close()


def test_threaded_router_stall_detected(engine_parts):
    """When the policy itself never places the queue head (least_pages
    against an impossible reservation) every worker parks and the drive
    loop must raise the router-stall error, mirroring the sequential
    executor's behavior."""
    cfg, params, dsg = engine_parts
    router = Router(cfg, params, dsg, n_replicas=2, policy="least_pages",
                    exec_mode="threaded", n_slots=2, max_seq=64,
                    prompt_bucket=32, cache_backend="paged", page_size=8,
                    cache_tokens=16)
    router.submit(Request(uid=0, prompt=np.zeros(30, np.int32),
                          max_new=16))
    with pytest.raises(RuntimeError, match="router stalled"):
        router.run(max_steps=2_000)
    router.close()
