"""repro-lint: checker behavior on a fixture corpus + the live tree.

Each known-bad snippet is written into a throwaway package and run
through `run_lint`; the checker must produce EXACTLY the expected
finding (no more — false positives on the paired known-good snippet are
failures too).  The live-tree test pins src/ clean against the
checked-in baseline, so a genuine new violation fails the suite the
same way it fails CI's lint job.

The REPRO_TSAN tests exercise the dynamic half of the lock-discipline
contract (analysis/contracts.py): the guarded containers raise
TsanViolation on undisciplined mutations and stay silent under the
documented protocol.
"""
import collections
import json
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis.contracts import (CheckedCondition, GuardedDeque,
                                      GuardedDict, GuardedList,
                                      TsanViolation)
from repro.analysis.findings import Finding, load_baseline
from repro.analysis.runner import run_lint

REPO = Path(__file__).resolve().parent.parent


def lint_snippet(tmp_path, source, filename="mod.py"):
    root = tmp_path / "src"
    root.mkdir(exist_ok=True)
    (root / filename).write_text(source)
    return run_lint(root)


def codes(findings):
    return [f.code for f in findings]


# ---------------------------------------------------------------------------
# jit hygiene
# ---------------------------------------------------------------------------

BAD_HOST_SYNC = """\
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    y = jnp.sum(x)
    return y.item()
"""

BAD_COERCION = """\
import jax

@jax.jit
def f(x):
    return float(x * 2)
"""

BAD_BRANCH = """\
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    if jnp.sum(x) > 0:
        return x
    return -x
"""

BAD_CLOSURE = """\
import jax

class Engine:
    def __init__(self):
        self.steps = 0
        def _step(tok):
            return tok + self.steps
        self._jit_step = jax.jit(_step)
"""

GOOD_JIT = """\
import jax
import jax.numpy as jnp
from functools import partial

@partial(jax.jit, static_argnames=("block",))
def f(x, block):
    if block > 128:          # static arg: host branch is fine
        x = x * 2
    n = x.shape[0]           # attribute access kills taint
    return jnp.sum(x) / n
"""


def test_host_sync_in_jit(tmp_path):
    assert codes(lint_snippet(tmp_path, BAD_HOST_SYNC)) == ["JIT101"]


def test_coercion_of_traced_value(tmp_path):
    assert codes(lint_snippet(tmp_path, BAD_COERCION)) == ["JIT102"]


def test_branch_on_tracer(tmp_path):
    assert codes(lint_snippet(tmp_path, BAD_BRANCH)) == ["JIT104"]


def test_jitted_closure_captures_self(tmp_path):
    assert codes(lint_snippet(tmp_path, BAD_CLOSURE)) == ["JIT105"]


def test_static_branching_is_clean(tmp_path):
    assert lint_snippet(tmp_path, GOOD_JIT) == []


def test_non_hashable_static_default(tmp_path):
    src = (
        "import jax\n"
        "from functools import partial\n\n"
        "@partial(jax.jit, static_argnames=('shape',))\n"
        "def f(x, shape=[1, 2]):\n"
        "    return x\n")
    assert codes(lint_snippet(tmp_path, src)) == ["JIT106"]


# ---------------------------------------------------------------------------
# lock discipline
# ---------------------------------------------------------------------------

BAD_UNLOCKED = """\
import threading
from repro.analysis.contracts import locked_by

@locked_by("_cond", "_idle")
class Executor:
    def __init__(self):
        self._cond = threading.Condition()
        self._idle = [True]

    def park(self, i):
        self._idle[i] = True            # no lock: LCK201
"""

GOOD_LOCKED = """\
import threading
from repro.analysis.contracts import locked_by, owned_by, runs_on, exempt

@locked_by("_cond", "_idle")
@owned_by("worker", "queue")
class Executor:
    def __init__(self):
        self._cond = threading.Condition()
        self._idle = [True]
        self.queue = []

    def park(self, i):
        with self._cond:
            self._idle[i] = True        # locked: fine

    @runs_on("worker")
    def admit(self):
        self.queue.pop()                # owner role: fine

    @exempt("queue", reason="external entry; serialized upstream")
    def submit(self, r):
        self.queue.append(r)            # waived with a reason: fine
"""


def test_unlocked_mutation_flagged(tmp_path):
    found = lint_snippet(tmp_path, BAD_UNLOCKED)
    assert codes(found) == ["LCK201"]
    assert "_idle" in found[0].message


def test_lock_discipline_clean(tmp_path):
    assert lint_snippet(tmp_path, GOOD_LOCKED) == []


def test_owned_field_outside_owner(tmp_path):
    src = (
        "from repro.analysis.contracts import owned_by\n\n"
        "@owned_by('worker', 'done')\n"
        "class Engine:\n"
        "    def __init__(self):\n"
        "        self.done = {}\n"
        "    def merge(self, k, v):\n"
        "        self.done[k] = v\n")
    assert codes(lint_snippet(tmp_path, src)) == ["LCK202"]


# ---------------------------------------------------------------------------
# pallas contracts
# ---------------------------------------------------------------------------

BAD_ENV_READ = """\
import os

def use_interpret():
    return os.environ.get("REPRO_INTERPRET", "") == "1"
"""


def test_raw_interpret_read_flagged(tmp_path):
    assert codes(lint_snippet(tmp_path, BAD_ENV_READ)) == ["PAL301"]


def test_interpret_read_allowed_in_ops(tmp_path):
    root = tmp_path / "src" / "repro" / "kernels"
    root.mkdir(parents=True)
    (root / "ops.py").write_text(BAD_ENV_READ)
    assert run_lint(tmp_path / "src") == []


def test_traced_grid_flagged(tmp_path):
    src = (
        "import jax.numpy as jnp\n"
        "from jax.experimental import pallas as pl\n\n"
        "def run(x, kernel):\n"
        "    return pl.pallas_call(\n"
        "        kernel, out_shape=x,\n"
        "        grid=(jnp.ceil(x.shape[0] / 8),))(x)\n")
    assert codes(lint_snippet(tmp_path, src)) == ["PAL302"]


def test_host_numpy_index_map_flagged(tmp_path):
    src = (
        "import numpy as np\n"
        "from jax.experimental import pallas as pl\n\n"
        "spec = pl.BlockSpec((8, 8), lambda i: (np.int32(i), 0))\n")
    assert codes(lint_snippet(tmp_path, src)) == ["PAL303"]


BAD_INTERPRET_LITERAL = """\
from jax.experimental import pallas as pl

def run(x, kernel):
    return pl.pallas_call(kernel, out_shape=x, interpret=True)(x)
"""

GOOD_INTERPRET_THREADED = """\
from jax.experimental import pallas as pl

def run(x, kernel, interpret=False):
    return pl.pallas_call(kernel, out_shape=x, interpret=interpret)(x)
"""


def test_interpret_literal_outside_kernels_flagged(tmp_path):
    assert codes(lint_snippet(tmp_path, BAD_INTERPRET_LITERAL)) \
        == ["PAL304"]


def test_interpret_literal_allowed_in_kernels(tmp_path):
    # kernel modules DEFAULT the kwarg (interpret: bool = False) and the
    # ops.py wrappers thread the policy — a literal there is the
    # documented layering, not a fork
    root = tmp_path / "src" / "repro" / "kernels"
    root.mkdir(parents=True)
    (root / "mod.py").write_text(BAD_INTERPRET_LITERAL)
    assert run_lint(tmp_path / "src") == []


def test_interpret_threaded_variable_is_clean(tmp_path):
    assert lint_snippet(tmp_path, GOOD_INTERPRET_THREADED) == []


def test_clamped_index_map_is_clean(tmp_path):
    # jnp clamps inside index maps are the paged-attention idiom: index
    # maps are traced, so jnp is legal there (and np is legal in grids)
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "from jax.experimental import pallas as pl\n\n"
        "def run(x, kernel, n):\n"
        "    spec = pl.BlockSpec((8, 8), lambda i, r: (jnp.minimum(i, r), 0))\n"
        "    return pl.pallas_call(kernel, out_shape=x,\n"
        "                          grid=(int(np.ceil(n / 8)),))(x)\n")
    assert lint_snippet(tmp_path, src) == []


# ---------------------------------------------------------------------------
# pytree registration
# ---------------------------------------------------------------------------

BAD_PYTREE = """\
import jax
from dataclasses import dataclass

@dataclass
class Carry:
    total: object

@jax.jit
def f(x):
    return Carry(total=x.sum())
"""

GOOD_PYTREE = """\
import jax
from dataclasses import dataclass

@jax.tree_util.register_pytree_node_class
@dataclass
class Carry:
    total: object

    def tree_flatten(self):
        return (self.total,), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

@jax.jit
def f(x):
    return Carry(total=x.sum())
"""


def test_unregistered_dataclass_flagged(tmp_path):
    found = lint_snippet(tmp_path, BAD_PYTREE)
    assert codes(found) == ["PYT401"]
    assert "Carry" in found[0].message


def test_registered_dataclass_clean(tmp_path):
    assert lint_snippet(tmp_path, GOOD_PYTREE) == []


# ---------------------------------------------------------------------------
# live tree + CLI
# ---------------------------------------------------------------------------

def test_src_tree_clean_against_baseline():
    findings = run_lint(REPO / "src")
    baseline = load_baseline(REPO / "scripts" / "lint_baseline.json")
    new, _ = baseline.split(findings)
    assert new == [], "\n".join(f.render() for f in new)


@pytest.mark.parametrize("snippet", [BAD_HOST_SYNC, BAD_UNLOCKED,
                                     BAD_ENV_READ, BAD_PYTREE],
                         ids=["host-sync", "unlocked", "env-read",
                              "pytree"])
def test_cli_exits_nonzero_on_bad_snippet(tmp_path, snippet):
    bad = tmp_path / "pkg"
    bad.mkdir()
    (bad / "mod.py").write_text(snippet)
    script = str(REPO / "scripts" / "run_lint.py")
    r = subprocess.run(
        [sys.executable, script, "--root", str(bad), "--fail-on-new",
         "--baseline", str(tmp_path / "empty_baseline.json")],
        capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "new finding" in r.stdout


def test_cli_clean_on_src_with_baseline():
    script = str(REPO / "scripts" / "run_lint.py")
    ok = subprocess.run([sys.executable, script, "--fail-on-new"],
                        capture_output=True, text=True, cwd=REPO)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "clean" in ok.stdout


def test_finding_fingerprint_stable_across_line_drift():
    a = Finding(file="m.py", line=10, col=0, code="JIT101",
                checker="jit_hygiene", message="msg", context="m.f")
    b = Finding(file="m.py", line=99, col=4, code="JIT101",
                checker="jit_hygiene", message="msg", context="m.f")
    assert a.fingerprint == b.fingerprint


def test_baseline_reason_required(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"accepted": [
        {"fingerprint": "m.py::JIT101::m.f::msg", "reason": ""}]}))
    baseline = load_baseline(path)
    assert baseline.unreasoned() == ["m.py::JIT101::m.f::msg"]


# ---------------------------------------------------------------------------
# REPRO_TSAN runtime shim
# ---------------------------------------------------------------------------

class StubEngine:
    """Duck-typed engine: just enough surface for ThreadedExecutor."""

    def __init__(self):
        self.queue = collections.deque()
        self.done = {}
        self.slots = []

    def submit(self, req):
        self.queue.append(req)


@pytest.fixture
def tsan_executor(monkeypatch):
    monkeypatch.setenv("REPRO_TSAN", "1")
    from repro.serving.parallel_exec import ThreadedExecutor
    ex = ThreadedExecutor([StubEngine(), StubEngine()])
    yield ex
    ex.close()


def test_tsan_wraps_state(tsan_executor):
    ex = tsan_executor
    assert isinstance(ex._cond, CheckedCondition)
    assert isinstance(ex._idle, GuardedList)
    assert isinstance(ex.busy_seconds, GuardedList)
    assert isinstance(ex.engines[0].queue, GuardedDeque)
    assert isinstance(ex.engines[0].done, GuardedDict)


def test_tsan_allows_locked_and_quiescent_mutation(tsan_executor):
    ex = tsan_executor
    with ex._cond:
        ex._idle[0] = False              # locked: fine
        ex._idle[0] = True
    ex.engines[0].queue.append("r")      # quiescent (no owner): fine
    ex.dispatch(1, "r2")                 # the documented protocol
    assert list(ex.engines[1].queue) == ["r2"]


def test_tsan_catches_unlocked_mutation(tsan_executor):
    ex = tsan_executor
    t = threading.Thread(target=lambda: None)
    t.start(); t.join()
    ex._idle.set_owner(t)                # another thread owns it
    with pytest.raises(TsanViolation, match="_idle"):
        ex._idle[0] = False
    ex._idle.set_owner(None)


def test_tsan_catches_cross_thread_engine_mutation(tsan_executor):
    ex = tsan_executor
    err = []
    ex.engines[0].queue.set_owner(threading.current_thread())

    def intruder():
        try:
            ex.engines[0].queue.append("stolen")
        except TsanViolation as e:
            err.append(e)

    t = threading.Thread(target=intruder)
    t.start(); t.join()
    assert err, "cross-thread unlocked mutation must raise"
    ex.engines[0].queue.set_owner(None)


def test_tsan_wait_requires_lock(tsan_executor):
    with pytest.raises(TsanViolation):
        tsan_executor._cond.wait(0.01)


def test_tsan_off_uses_plain_state(monkeypatch):
    monkeypatch.delenv("REPRO_TSAN", raising=False)
    from repro.serving.parallel_exec import ThreadedExecutor
    ex = ThreadedExecutor([StubEngine()])
    assert type(ex._idle) is list
    assert type(ex.engines[0].queue) is collections.deque
    ex.close()


def test_tsan_reset_timing_rewraps(tsan_executor):
    ex = tsan_executor
    with ex._cond:
        ex.busy_seconds[0] = 1.5
    ex.reset_timing()
    assert isinstance(ex.busy_seconds, GuardedList)
    assert ex.busy_seconds == [0.0, 0.0]


# ---------------------------------------------------------------------------
# workload latency stats (satellite: no more silent 0.0 percentiles)
# ---------------------------------------------------------------------------

def test_latency_stats_raise_on_empty():
    from repro.serving.workload import latency_stats
    with pytest.raises(ValueError, match="finished request"):
        latency_stats({})


def test_latency_stats_percentiles():
    from repro.serving.scheduler import Request
    from repro.serving.workload import latency_stats
    import numpy as np
    done = {}
    for uid, lat in enumerate([1.0, 2.0, 3.0, 4.0]):
        r = Request(uid=uid, prompt=np.zeros(4, np.int32))
        r.submitted, r.finished = 10.0, 10.0 + lat
        r.status = "ok"
        done[uid] = r
    stats = latency_stats(done)
    assert stats["p50_s"] == pytest.approx(2.5)
    assert stats["p95_s"] == pytest.approx(3.85)
    assert stats["ok_requests"] == 4
    assert stats["failed_requests"] == 0
    assert stats["timed_out_requests"] == 0


def test_latency_stats_excludes_non_ok():
    """A timed-out request's finish stamp is exactly its deadline —
    folding it into p50/p95 reports the SLO ceiling as an observed
    latency.  Percentiles must cover status == 'ok' only, with non-ok
    outcomes surfaced as counts."""
    from repro.serving.scheduler import Request
    from repro.serving.workload import latency_stats
    import numpy as np
    done = {}
    for uid, (lat, status) in enumerate(
            [(1.0, "ok"), (2.0, "ok"), (3.0, "ok"), (4.0, "ok"),
             (60.0, "timed_out"), (45.0, "failed")]):
        r = Request(uid=uid, prompt=np.zeros(4, np.int32))
        r.submitted, r.finished = 10.0, 10.0 + lat
        r.status = status
        done[uid] = r
    stats = latency_stats(done)
    # identical to the all-ok run above: the 60s/45s non-ok latencies
    # must not move the percentiles
    assert stats["p50_s"] == pytest.approx(2.5)
    assert stats["p95_s"] == pytest.approx(3.85)
    assert stats["ok_requests"] == 4
    assert stats["failed_requests"] == 1
    assert stats["timed_out_requests"] == 1
    # all-non-ok: percentiles are undefined, not 0.0
    bad = {u: r for u, r in done.items() if r.status != "ok"}
    with pytest.raises(ValueError, match="status"):
        latency_stats(bad)


def _stamped_request(uid, *, submitted=10.0, first=None, finished=None,
                     n_out=0, status="ok"):
    from repro.serving.scheduler import Request
    import numpy as np
    r = Request(uid=uid, prompt=np.zeros(4, np.int32))
    r.submitted = submitted
    r.first_token = 0.0 if first is None else first
    r.finished = finished if finished is not None else submitted + 1.0
    r.output = list(range(n_out))
    r.status = status
    return r


def test_latency_stats_ttft_tpot_split():
    """TTFT = submit -> first token; TPOT = (finish - first token) /
    (output tokens - 1).  Four ok requests with hand-picked stamps pin
    both percentile pairs."""
    from repro.serving.workload import latency_stats
    done = {}
    # ttft values: 0.1, 0.2, 0.3, 0.4; each emits 5 tokens over the 4
    # post-first-token gaps -> tpot 0.1, 0.2, 0.3, 0.4 as well
    for uid, ttft in enumerate([0.1, 0.2, 0.3, 0.4]):
        done[uid] = _stamped_request(
            uid, submitted=10.0, first=10.0 + ttft,
            finished=10.0 + ttft + 4 * ttft, n_out=5)
    stats = latency_stats(done)
    assert stats["ttft_p50_s"] == pytest.approx(0.25)
    assert stats["ttft_p95_s"] == pytest.approx(0.385)
    assert stats["tpot_p50_s"] == pytest.approx(0.25)
    assert stats["tpot_p95_s"] == pytest.approx(0.385)
    # the end-to-end percentiles still cover submit -> finish
    assert stats["p50_s"] == pytest.approx(1.25)


def test_latency_stats_ttft_tpot_exclude_non_ok():
    """Failed/timed-out requests must not leak into the TTFT/TPOT
    percentiles (same exclusion contract as p50/p95), and the ValueError
    semantics are unchanged for empty / all-non-ok inputs."""
    from repro.serving.workload import latency_stats
    done = {}
    for uid, ttft in enumerate([0.1, 0.2, 0.3, 0.4]):
        done[uid] = _stamped_request(
            uid, submitted=10.0, first=10.0 + ttft,
            finished=10.0 + ttft + 4 * ttft, n_out=5)
    done[90] = _stamped_request(90, first=40.0, finished=50.0, n_out=5,
                                status="timed_out")
    done[91] = _stamped_request(91, first=30.0, finished=60.0, n_out=5,
                                status="failed")
    stats = latency_stats(done)
    assert stats["ttft_p50_s"] == pytest.approx(0.25)   # unmoved
    assert stats["tpot_p95_s"] == pytest.approx(0.385)  # unmoved
    assert stats["failed_requests"] == 1
    assert stats["timed_out_requests"] == 1
    with pytest.raises(ValueError, match="finished request"):
        latency_stats({})
    bad = {u: r for u, r in done.items() if r.status != "ok"}
    with pytest.raises(ValueError, match="status"):
        latency_stats(bad)


def test_latency_stats_omits_unavailable_splits():
    """No silent 0.0: requests without a first_token stamp (recorded
    before the stamp existed) contribute no TTFT sample, and 0/1-token
    outputs contribute no TPOT sample — when NO ok request qualifies the
    keys are omitted entirely."""
    from repro.serving.workload import latency_stats
    # no stamps at all -> neither split reported
    done = {0: _stamped_request(0, n_out=3), 1: _stamped_request(1, n_out=3)}
    stats = latency_stats(done)
    assert "ttft_p50_s" not in stats and "tpot_p50_s" not in stats
    assert stats["ok_requests"] == 2
    # stamped but single-token: TTFT reported, TPOT undefined (no
    # inter-token gap exists)
    done = {0: _stamped_request(0, first=10.25, finished=10.25, n_out=1)}
    stats = latency_stats(done)
    assert stats["ttft_p50_s"] == pytest.approx(0.25)
    assert "tpot_p50_s" not in stats and "tpot_p95_s" not in stats
