"""Copy-on-write shared-prefix paging (PR 10).

Layers under test, bottom up:

  * kv_cache.prefix_chain — chained content hashes over page-sized
    blocks of the padded prompt row (the sharing index keys).
  * BlockAllocator — refcounted page lifecycle: alloc/share/free,
    live-only prefix index (entries drop the instant their page's
    refcount reaches zero), double-free and freed-page registration
    rejected.
  * PagedBackend sharing surface — shared_hits leading-run semantics,
    admission arithmetic (sharing_adjustment / can_admit), write-time
    page mapping, and the COW triggers in ensure / ensure_range.
  * ServingEngine / Router differentials (tests/harness.py): sharing
    on vs off produces bitwise-identical greedy streams across
    {dense-reference, paged} x {1, 2} replicas x decode_chunk {1, 8},
    including retire/readmit reuse and a chaos-kill failover.

Sharing only helps when padded prompt rows coincide, so traffic here
uses fixed prompt lengths (see harness.shared_prefix_traffic); the
COW cases use identical prompts in a non-multiple-of-page bucket so a
partial tail page is shared and the first decode write must copy.
"""
import numpy as np
import pytest

from harness import (CHUNK_AXIS, assert_streams_equal, engine_spec,
                     make_engine_parts, run_and_collect,
                     shared_prefix_traffic)
from repro.runtime.fault_tolerance import ReplicaFault
from repro.serving import kv_cache
from repro.serving.kv_cache import (BlockAllocator, OutOfPages,
                                    prefix_chain)

PAGE = 16


@pytest.fixture(scope="module")
def parts():
    return make_engine_parts()     # threshold_mode="topk": lanes independent


def _paged_kw(**extra):
    kw = dict(cache_backend="paged", page_size=PAGE, cache_tokens=256)
    kw.update(extra)
    return kw


# ---------------------------------------------------------------------------
# prefix_chain: content-hash keys
# ---------------------------------------------------------------------------

def test_prefix_chain_is_chained_and_deterministic():
    row = np.arange(48, dtype=np.int32)
    chain = prefix_chain(row, PAGE)
    assert len(chain) == 3 and all(isinstance(k, bytes) for k in chain)
    assert chain == prefix_chain(row.copy(), PAGE)
    # a ragged tail gets its own (shorter-block) key
    assert len(prefix_chain(np.arange(40, dtype=np.int32), PAGE)) == 3
    # chaining: equal blocks at depth i only collide when ALL earlier
    # blocks also match
    other = row.copy()
    other[0] = 999
    diverged = prefix_chain(other, PAGE)
    assert diverged[0] != chain[0]
    assert diverged[1] != chain[1]          # same block 1, different prefix
    assert diverged[2] != chain[2]


def test_prefix_chain_validates_input():
    with pytest.raises(ValueError):
        prefix_chain(np.zeros((2, 4), np.int32), PAGE)
    # non-int32 rows are canonicalised, not rejected: the key hashes
    # int32 bytes regardless of the caller's dtype
    assert (prefix_chain(np.arange(8), PAGE)
            == prefix_chain(np.arange(8, dtype=np.int32), PAGE))


# ---------------------------------------------------------------------------
# BlockAllocator: refcounts + live-only index
# ---------------------------------------------------------------------------

def test_allocator_share_free_lifecycle():
    a = BlockAllocator(8, reserved=1)
    p, q = a.alloc(2)
    assert a.refcount(p) == 1 and a.live_pages == 2
    assert a.share(p) == 2
    free_before = a.free_pages
    a.free([p])                              # rc 2 -> 1: stays live
    assert a.refcount(p) == 1 and a.free_pages == free_before
    a.free([p, q])                           # both hit zero
    assert a.live_pages == 0 and a.free_pages == free_before + 2
    with pytest.raises(ValueError):
        a.free([p])                          # double free
    with pytest.raises(ValueError):
        a.share(p)                           # share of a freed page


def test_allocator_index_is_live_only():
    a = BlockAllocator(4)
    (p,) = a.alloc(1)
    a.register(b"k0", p)
    assert a.lookup(b"k0") == p and a.index_size == 1
    a.register(b"k0", p)                     # idempotent re-register
    assert a.index_size == 1
    a.free([p])
    assert a.lookup(b"k0") is None and a.index_size == 0
    with pytest.raises(ValueError):
        a.register(b"k1", p)                 # freed page can't be indexed
    # a recycled id may be re-registered once it is live again
    pages = a.alloc(a.free_pages)
    assert p in pages
    a.register(b"k2", p)
    assert a.lookup(b"k2") == p


def test_allocator_exhaustion_and_peak():
    a = BlockAllocator(3)
    got = a.alloc(3)
    with pytest.raises(OutOfPages):
        a.alloc(1)
    # sharing never consumes free pages
    a.share(got[0])
    assert a.free_pages == 0 and a.peak_live == 3
    a.free(got + [got[0]])
    a.reset_peak()
    assert a.peak_live == a.live_pages == 0


# ---------------------------------------------------------------------------
# PagedBackend: shared_hits / admission arithmetic / write contracts
# ---------------------------------------------------------------------------

def _mini_backend(**kw):
    from harness import smoke_cfg
    be = kv_cache.get_backend("paged", page_size=4, total_tokens=64,
                              prefix_sharing=True, **kw)
    handle = be.make(smoke_cfg(), n_slots=2, max_seq=16)
    return be, handle


def test_shared_hits_is_a_leading_run():
    be, _ = _mini_backend()
    row = np.arange(12, dtype=np.int32)
    chain = prefix_chain(row, 4)
    assert be.shared_hits(chain) == 0
    pages = be.allocator.alloc(2)
    be.allocator.register(chain[0], pages[0])
    be.allocator.register(chain[2], pages[1])   # hole at depth 1
    assert be.shared_hits(chain) == 1           # stops at the first miss
    assert be.shared_hits(None) == 0


def test_can_admit_accounts_for_sharing():
    be, _ = _mini_backend()
    row = np.arange(8, dtype=np.int32)
    chain = prefix_chain(row, 4)
    base_free = be.allocator.free_pages
    # no sharing context: worst case, pages_for(12) = 3
    assert be.can_admit(12)
    # full-page prefix resident -> one fewer page needed
    pages = be.allocator.alloc(2)
    for k, p in zip(chain, pages):
        be.allocator.register(k, p)
    adj = be.sharing_adjustment(chain, prompt_tokens=8)
    assert adj == -2                            # two full pages resident
    # a ragged prompt charges a +1 COW reserve; with its leading full
    # block resident the hits discount nets the two out
    ragged = prefix_chain(np.arange(6, dtype=np.int32), 4)
    assert be.sharing_adjustment(ragged, prompt_tokens=6) == 0  # +1 -1
    fresh = prefix_chain(np.arange(100, 106, dtype=np.int32), 4)
    assert be.sharing_adjustment(fresh, prompt_tokens=6) == 1   # +1 -0
    be.allocator.free(pages)                    # rc back to zero
    assert be.allocator.free_pages == base_free


def test_write_slot_kv_none_requires_full_coverage():
    be, handle = _mini_backend()
    row = np.arange(8, dtype=np.int32)
    chain = prefix_chain(row, 4)
    with pytest.raises(ValueError, match="slot_kv"):
        be.write(handle, None, 0, n_tokens=8, reserve_tokens=12,
                 chain=chain)


# ---------------------------------------------------------------------------
# engine differentials: sharing on == sharing off == dense reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", CHUNK_AXIS)
@pytest.mark.parametrize("n_replicas", [None, 2])
def test_sharing_streams_bitwise_equal(parts, chunk, n_replicas):
    """Overlapping-prefix traffic: greedy streams with prefix sharing
    on are bitwise identical to both the paged sharing-off run and the
    dense reference, across replica counts and decode chunk sizes."""
    cfg = parts[0]
    rep = {} if n_replicas is None else {"n_replicas": n_replicas,
                                         "policy": "round_robin"}
    ref = run_and_collect(
        engine_spec(*parts, decode_chunk=chunk, **rep),
        shared_prefix_traffic(cfg), max_steps=2000)
    off = run_and_collect(
        engine_spec(*parts, decode_chunk=chunk, **_paged_kw(), **rep),
        shared_prefix_traffic(cfg), max_steps=2000)
    on, eng = run_and_collect(
        engine_spec(*parts, decode_chunk=chunk,
                    **_paged_kw(prefix_sharing=True), **rep),
        shared_prefix_traffic(cfg), max_steps=2000, return_engine=True)
    assert_streams_equal(ref, off, "dense vs paged")
    assert_streams_equal(ref, on, "dense vs paged+sharing")
    backends = ([e.backend for e in eng.engines]
                if n_replicas else [eng.backend])
    assert sum(b.shared_page_hits for b in backends) > 0
    for b in backends:                       # clean drain, empty index
        assert b.allocator.live_pages == 0
        assert b.allocator.index_size == 0
    if n_replicas:
        eng.close()


@pytest.mark.parametrize("chunk", CHUNK_AXIS)
def test_cow_partial_tail_streams_and_counters(parts, chunk):
    """Identical prompts in a 24-token bucket (page_size 16) share a
    partial tail page, so every lane's first decode write lands on a
    shared page and must copy.  Streams stay bitwise equal to the
    sharing-off run and every sharer COWs exactly once."""
    cfg = parts[0]
    n = 5
    reqs = lambda: shared_prefix_traffic(  # noqa: E731
        cfg, n=n, prompt_len=24, prefix_len=24, max_new=6)
    spec = dict(buckets=(24,), decode_chunk=chunk)
    off = run_and_collect(
        engine_spec(*parts, **_paged_kw(), **spec), reqs(),
        max_steps=2000)
    on, eng = run_and_collect(
        engine_spec(*parts, **_paged_kw(prefix_sharing=True), **spec),
        reqs(), max_steps=2000, return_engine=True)
    assert_streams_equal(off, on, f"chunk={chunk}")
    # sharers replay the cached prefill while the registrant's pages
    # are resident, and every holder of the shared tail page COWs it on
    # first decode write — at most once per residency
    assert eng.prefill_cache_hits >= 1
    assert 1 <= eng.backend.cow_copies <= n
    assert eng.backend.shared_page_hits >= eng.prefill_cache_hits
    assert eng.backend.allocator.live_pages == 0


def test_sharing_reduces_peak_pages(parts):
    """The point of the tentpole: resident pages shrink when prompts
    overlap.  Identical 24-token prompts keep only one shared prompt
    copy, so sharing must beat the unshared peak."""
    cfg = parts[0]
    reqs = lambda: shared_prefix_traffic(  # noqa: E731
        cfg, n=6, prompt_len=24, prefix_len=24, max_new=4)
    spec = dict(buckets=(24,), n_slots=3)
    _, off = run_and_collect(
        engine_spec(*parts, **_paged_kw(), **spec), reqs(),
        max_steps=2000, return_engine=True)
    _, on = run_and_collect(
        engine_spec(*parts, **_paged_kw(prefix_sharing=True), **spec),
        reqs(), max_steps=2000, return_engine=True)
    assert on.backend.allocator.peak_live < off.backend.allocator.peak_live


def test_retire_readmit_reuses_and_reclaims(parts):
    """Two waves of identical traffic through one engine: wave 2
    re-registers the (fully reclaimed) pages, shares within the wave,
    and reproduces wave 1's streams bitwise."""
    from repro.serving.scheduler import ServingEngine
    cfg, params, dsg = parts
    eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                        buckets=(24,), admission="overlap",
                        cache_backend="paged", page_size=PAGE,
                        cache_tokens=256, prefix_sharing=True)
    wave1 = shared_prefix_traffic(cfg, n=4, prompt_len=24, prefix_len=24,
                                  max_new=6)
    for r in wave1:
        eng.submit(r)
    done1 = dict(eng.run(max_steps=2000))
    assert eng.backend.allocator.live_pages == 0      # full reclaim
    assert eng.backend.allocator.index_size == 0      # index died with rc=0
    hits1 = eng.backend.shared_page_hits
    wave2 = [type(r)(uid=r.uid + 100, prompt=r.prompt.copy(),
                     max_new=r.max_new) for r in wave1]
    for r in wave2:
        eng.submit(r)
    done2 = eng.run(max_steps=2000)
    assert eng.backend.shared_page_hits > hits1       # re-shared after reuse
    for r in wave1:
        assert list(done1[r.uid].output) == list(done2[r.uid + 100].output)
    assert eng.backend.allocator.live_pages == 0


def test_chaos_kill_failover_with_sharing(parts):
    """Replica 1 killed mid-decode with sharing enabled: the dead
    replica's reset decrements (never double-frees) its shared pages,
    and survivors replay the victims to bitwise-equal streams."""
    from repro.serving.router import FaultToleranceConfig
    cfg = parts[0]
    ref = run_and_collect(engine_spec(*parts),
                          shared_prefix_traffic(cfg), max_steps=2000)
    rep = dict(n_replicas=3, policy="round_robin",
               fault_tolerance=FaultToleranceConfig(
                   max_replica_restarts=0, max_retries=3))
    streams, router = run_and_collect(
        engine_spec(*parts, **_paged_kw(prefix_sharing=True), **rep),
        shared_prefix_traffic(cfg), max_steps=8000, return_engine=True,
        faults=[ReplicaFault(replica=1, step=3)])
    try:
        assert router.health[1].state == "dead"
        assert_streams_equal(ref, streams, "chaos+sharing")
        for e in router.engines:             # incl. the dead replica
            assert e.backend.allocator.live_pages == 0
    finally:
        router.close()
