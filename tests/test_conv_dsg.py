"""Paper-native CONV path tests: im2col/VMM equivalence to lax.conv,
per-window DRS masking, and the CONV-ReLU-BN double-mask dataflow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv_dsg, drs
from repro.core.dsg_linear import DSGConfig


@pytest.mark.parametrize("rs", [(3, 3), (1, 1), (5, 5)])
def test_im2col_matches_lax_conv(rs):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 8, 3))
    cfg = DSGConfig(enabled=False)
    p = conv_dsg.init_conv_dsg(jax.random.PRNGKey(1), 3, rs, 16, cfg)
    patches = conv_dsg.im2col(x, rs)
    y = patches.reshape(-1, patches.shape[-1]) @ p["w"]
    y = y.reshape(2, 8, 8, 16)
    want = conv_dsg.conv2d_ref(p["w"], x, rs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_conv_dsg_masks_per_window():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 8, 8, 4))
    cfg = DSGConfig(enabled=True, gamma=0.5, block=8, eps=0.5)
    p = conv_dsg.init_conv_dsg(jax.random.PRNGKey(3), 4, (3, 3), 32, cfg)
    y, gmask = conv_dsg.conv2d_dsg(p, x, (3, 3), cfg)
    assert y.shape == (2, 8, 8, 32)
    assert gmask.shape == (2 * 8 * 8, 4)        # per-sliding-window masks
    k = drs.keep_groups(32, cfg.drs_cfg())
    np.testing.assert_array_equal(np.asarray(gmask.sum(-1)), k)
    # masked-out groups are exactly zero in the output
    ym = np.asarray(y).reshape(-1, 4, 8)
    gm = np.asarray(gmask)
    np.testing.assert_array_equal(ym[gm == 0], 0.0)


def test_conv_dsg_double_mask_bn_sparsity():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (4, 6, 6, 4))
    cfg = DSGConfig(enabled=True, gamma=0.5, block=8, eps=0.5)
    p = conv_dsg.init_conv_dsg(jax.random.PRNGKey(5), 4, (3, 3), 32, cfg)
    scale, bias = jnp.ones(32), jnp.ones(32) * 0.2
    y_d, gmask = conv_dsg.conv2d_dsg(p, x, (3, 3), cfg, scale, bias,
                                     mask_mode="double")
    y_s, _ = conv_dsg.conv2d_dsg(p, x, (3, 3), cfg, scale, bias,
                                 mask_mode="single")
    gm = np.asarray(gmask)
    yd = np.asarray(y_d).reshape(-1, 4, 8)
    ys = np.asarray(y_s).reshape(-1, 4, 8)
    np.testing.assert_array_equal(yd[gm == 0], 0.0)     # fully sparse
    assert (ys[gm == 0] != 0).mean() > 0.9              # BN densified
