"""Serving-side DSG sparsity runtime (PR 7).

Layers under test, bottom up:

  * core/sparse_mask.py — group-CSR representation: dense<->CSR round
    trips, pow2 bounds, overhead accounting.
  * core/dsg_linear.swiglu_csr — the three FFN executors (masked-dense
    reference, bounded XLA gather, Pallas CSR kernel) agree numerically;
    full-density CSR matches the plain dense FFN.
  * serving/dsg_runtime.py — host pattern state: admission seeding,
    per-lane thresholds, retirement, bounds, device-push caching,
    donor mirroring, the double-mask hook.
  * ServingEngine + Router differentials (tests/harness.py): identical
    greedy streams across FFN executors, cache backends, slot counts,
    and replica counts — and the modeled FLOP reduction of the measured
    window.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import (CHUNK_AXIS, assert_streams_equal, engine_spec,
                     make_engine_parts, mixed_traffic, run_and_collect)
from repro.core import double_mask as dm
from repro.core import dsg_linear as dl
from repro.core import sparse_mask
from repro.serving import dsg_runtime
from repro.serving.dsg_runtime import DSGRuntime, DSGServingConfig
from repro.serving.router import Router
from repro.serving.scheduler import ServingEngine


@pytest.fixture(scope="module")
def engine_parts():
    return make_engine_parts()     # threshold_mode="topk": lanes independent


# ---------------------------------------------------------------------------
# sparse_mask: group-CSR representation
# ---------------------------------------------------------------------------

def test_active_group_bound_pow2_capped():
    assert [sparse_mask.active_group_bound(c, 8) for c in
            (0, 1, 2, 3, 4, 5, 8, 9)] == [1, 1, 2, 4, 4, 8, 8, 8]
    assert sparse_mask.active_group_buckets(8) == (1, 2, 4, 8)
    assert sparse_mask.active_group_buckets(4) == (1, 2, 4)


def test_dense_csr_round_trip_and_canonical_padding():
    rng = np.random.default_rng(7)
    g = 8
    mask = (rng.random((3, 5, g)) < 0.4).astype(np.float32)
    mask[0, 0] = 0.0
    mask[0, 0, 3] = 1.0                      # single-group row
    bound = sparse_mask.active_group_bound(int(mask.sum(-1).max()), g)
    idx, counts = sparse_mask.dense_to_csr(jnp.asarray(mask), bound)
    idx, counts = np.asarray(idx), np.asarray(counts)
    assert np.array_equal(counts, mask.sum(-1).astype(np.int32))
    for r in np.ndindex(3, 5):
        c = counts[r]
        assert np.array_equal(idx[r][:c], np.flatnonzero(mask[r]))
        assert (idx[r][c:] == 0).all()       # canonical zero padding
    back = np.asarray(sparse_mask.csr_to_dense(
        jnp.asarray(idx), jnp.asarray(counts), g))
    assert np.array_equal(back, mask)


def test_csr_to_dense_ignores_padding_garbage():
    idx = jnp.asarray([[3, 7, 7, 7]])        # count 2: trailing 7s ignored
    dense = np.asarray(sparse_mask.csr_to_dense(idx, jnp.asarray([2]), 8))
    assert np.array_equal(np.flatnonzero(dense[0]), [3, 7])
    assert dense.max() == 1.0                # duplicates never exceed 1


def test_csr_overhead_bytes_units():
    # (L, B) rows of `bound` int32 indices + one int32 count each
    assert sparse_mask.csr_overhead_bytes((2, 4), 8) == 2 * 4 * (8 * 4 + 4)
    assert sparse_mask.csr_overhead_bytes((5,), 1, idx_bytes=2,
                                          count_bytes=2) == 5 * 4


# ---------------------------------------------------------------------------
# swiglu_csr: executor agreement
# ---------------------------------------------------------------------------

def _ffn_parts(seed=0, d=16, f=64, b=3, s=1):
    rng = np.random.default_rng(seed)
    p = {"w_gate": jnp.asarray(rng.standard_normal((d, f)), jnp.float32)
                   / np.sqrt(d),
         "w_up": jnp.asarray(rng.standard_normal((d, f)), jnp.float32)
                 / np.sqrt(d),
         "w_down": jnp.asarray(rng.standard_normal((f, d)), jnp.float32)
                   / np.sqrt(f)}
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    return p, x


def test_swiglu_csr_executors_agree():
    block, g, bound = 16, 4, 2
    p, x = _ffn_parts()
    idx = jnp.asarray([[0, 2], [1, 3], [3, 0]], jnp.int32)
    counts = jnp.asarray([2, 2, 1], jnp.int32)
    ref = dl.swiglu_csr_masked(p, x, idx, counts, block=block)
    for mode in ("xla", "kernel"):
        out = dl.swiglu_csr(p, x, idx, counts, block=block, apply=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6, err_msg=mode)


def test_swiglu_csr_full_density_matches_dense_ffn():
    block, g = 16, 4
    p, x = _ffn_parts(seed=1)
    idx = jnp.tile(jnp.arange(g, dtype=jnp.int32), (3, 1))
    counts = jnp.full((3,), g, jnp.int32)
    dense = dl.swiglu_dense(p, x)
    for mode in ("dense", "xla", "kernel"):
        out = dl.swiglu_csr(p, x, idx, counts, block=block, apply=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                                   atol=2e-6, err_msg=mode)


def test_swiglu_csr_kernel_rejects_multi_token_rows():
    p, x = _ffn_parts(s=4)
    idx = jnp.zeros((3, 1), jnp.int32)
    counts = jnp.ones((3,), jnp.int32)
    with pytest.raises(ValueError, match="decode step"):
        dl.swiglu_csr(p, x, idx, counts, block=16, apply="kernel")
    with pytest.raises(ValueError, match="unknown CSR FFN apply"):
        dl.swiglu_csr(p, x, idx, counts, block=16, apply="mosaic")


# ---------------------------------------------------------------------------
# dsg_runtime: host pattern state
# ---------------------------------------------------------------------------

def test_mirror_csr_copies_donor_rows_to_free_lanes():
    csr = {"idx": jnp.asarray(np.arange(2 * 3 * 2).reshape(2, 3, 2),
                              jnp.int32),
           "counts": jnp.asarray([[1, 2, 1], [2, 1, 2]], jnp.int32)}
    out = dsg_runtime.mirror_csr(csr, jnp.asarray([False, True, True]),
                                 jnp.int32(0))
    idx, counts = np.asarray(out["idx"]), np.asarray(out["counts"])
    for lane in (1, 2):
        assert np.array_equal(idx[:, lane], np.asarray(csr["idx"])[:, 0])
        assert np.array_equal(counts[:, lane],
                              np.asarray(csr["counts"])[:, 0])
    assert np.array_equal(idx[:, 0], np.asarray(csr["idx"])[:, 0])


def test_double_mask_csr_matches_dense_double_mask():
    rng = np.random.default_rng(3)
    block, g = 8, 4
    x = jnp.asarray(rng.standard_normal((3, g * block)), jnp.float32)
    mask = jnp.asarray((rng.random((3, g)) < 0.6), jnp.float32)
    idx, counts = sparse_mask.dense_to_csr(mask, g)

    def norm(z):
        return z / (1.0 + jnp.mean(jnp.abs(z), axis=-1, keepdims=True))

    want = dm.double_mask(norm, x, mask, block)
    got = dsg_runtime.double_mask_csr(norm, x, idx, counts, block=block,
                                      n_groups=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)


def test_as_serving_config_coercion():
    assert dsg_runtime.as_serving_config(None) is None
    assert dsg_runtime.as_serving_config(False) is None
    assert dsg_runtime.as_serving_config(True) == DSGServingConfig()
    scfg = DSGServingConfig(refresh_interval=3)
    assert dsg_runtime.as_serving_config(scfg) is scfg
    with pytest.raises(TypeError):
        dsg_runtime.as_serving_config({"refresh_interval": 3})


def test_runtime_topk_seeding_and_reset(engine_parts):
    cfg, _, _ = engine_parts
    rt = DSGRuntime(cfg, DSGServingConfig(), n_slots=3)
    assert rt.n_groups == 4 and rt.keep == 2
    assert rt.bound() == 1                   # all lanes parked
    scores = np.random.default_rng(0).standard_normal(
        (cfg.n_layers, rt.n_groups)).astype(np.float32)
    rt.set_lane_from_scores(1, scores)
    assert (rt.counts[:, 1] == rt.keep).all()      # exact top-k per layer
    for l in range(cfg.n_layers):
        want = np.sort(np.argsort(scores[l])[-rt.keep:])
        assert np.array_equal(rt.idx[l, 1, :rt.keep], want)
    assert rt.bound() == sparse_mask.active_group_bound(rt.keep,
                                                        rt.n_groups)
    rt.reset_lane(1)
    assert rt.bound() == 1 and not rt.lane_active.any()
    assert (rt.counts == 1).all()


def test_runtime_ema_deterministic_and_refresh_gates_on_lane(engine_parts):
    cfg, _, _ = engine_parts
    mk = lambda: DSGRuntime(cfg, DSGServingConfig(threshold="ema",
                                                  ema_decay=0.9),
                            n_slots=2)
    rng = np.random.default_rng(1)
    seed_scores = rng.standard_normal((cfg.n_layers, 4)).astype(np.float32)
    step_scores = rng.standard_normal(
        (cfg.n_layers, 2, 4)).astype(np.float32)
    a, b = mk(), mk()
    for rt in (a, b):
        rt.set_lane_from_scores(0, seed_scores)
        rt.update_from_scores(step_scores, lanes=[0, 1])
    assert np.array_equal(a.idx, b.idx)            # deterministic
    assert np.array_equal(a.counts, b.counts)
    assert np.array_equal(a.ema, b.ema)
    # lane 1 never admitted: update must not touch it
    assert (a.counts[:, 1] == 1).all() and not a.lane_active[1]


def test_runtime_device_csr_cache_invalidation(engine_parts):
    cfg, _, _ = engine_parts
    rt = DSGRuntime(cfg, DSGServingConfig(), n_slots=2)
    first = rt.device_csr(2)
    assert rt.device_csr(2) is first               # cached per version
    rt.set_lane_from_scores(0, np.ones((cfg.n_layers, 4), np.float32))
    assert rt.device_csr(2) is not first           # write invalidates
    sliced = rt.device_csr(1)
    assert sliced["idx"].shape == (cfg.n_layers, 2, 1)
    assert int(np.asarray(sliced["counts"]).max()) <= 1


def test_runtime_warm_bounds_by_threshold_mode(engine_parts):
    cfg, _, _ = engine_parts
    topk = DSGRuntime(cfg, DSGServingConfig(), n_slots=2)
    assert topk.warm_bounds() == (2,)              # pinned at keep
    ema = DSGRuntime(cfg, DSGServingConfig(threshold="ema"), n_slots=2)
    assert ema.warm_bounds() == (1, 2, 4)          # counts float


def test_runtime_validation_raises(engine_parts):
    cfg, _, _ = engine_parts
    with pytest.raises(ValueError, match="topk.*ema|'topk' or 'ema'"):
        DSGRuntime(cfg, DSGServingConfig(threshold="shared"), n_slots=2)
    with pytest.raises(ValueError, match="refresh_interval"):
        DSGRuntime(cfg, DSGServingConfig(refresh_interval=0), n_slots=2)
    off = cfg.replace(dsg=cfg.dsg._replace(enabled=False))
    with pytest.raises(ValueError, match="enabled"):
        DSGRuntime(off, DSGServingConfig(), n_slots=2)


def test_flop_stats_accounting(engine_parts):
    cfg, _, _ = engine_parts
    rt = DSGRuntime(cfg, DSGServingConfig(), n_slots=2)
    with pytest.raises(ValueError, match="no decode steps"):
        rt.flop_stats()
    rt.set_lane_from_scores(0, np.random.default_rng(2).standard_normal(
        (cfg.n_layers, 4)).astype(np.float32))
    rt.record_step(active=[0], bound=rt.bound())
    st = rt.flop_stats()
    assert st["dense_units"] == cfg.n_layers * 4
    assert st["csr_units"] == cfg.n_layers * rt.keep
    assert st["flop_reduction_csr"] == pytest.approx(4 / rt.keep)


# ---------------------------------------------------------------------------
# engine + router differentials (bitwise greedy streams)
# ---------------------------------------------------------------------------

_DSG = DSGServingConfig(refresh_interval=4)


def _spec(parts, apply_mode, **kw):
    cfg, params, dsg = parts
    return engine_spec(cfg.replace(dsg_ffn_apply=apply_mode), params, dsg,
                       dsg_serving=_DSG, **kw)


@pytest.fixture(scope="module")
def reference_streams(engine_parts):
    """Masked-dense reference: full FFN matmuls, pattern applied as an
    expanded mask — the bitwise ground truth for every executor."""
    return run_and_collect(_spec(engine_parts, "dense"),
                           mixed_traffic(engine_parts[0]))


@pytest.mark.parametrize("apply_mode", ["xla", "kernel"])
def test_sparse_executors_match_dense_reference(engine_parts,
                                                reference_streams,
                                                apply_mode):
    got = run_and_collect(_spec(engine_parts, apply_mode),
                          mixed_traffic(engine_parts[0]))
    assert_streams_equal(reference_streams, got, f"apply={apply_mode}")


def test_paged_backend_matches_dense_backend(engine_parts,
                                             reference_streams):
    got = run_and_collect(
        _spec(engine_parts, "xla", cache_backend="paged", page_size=8,
              cache_tokens=160),
        mixed_traffic(engine_parts[0]))
    assert_streams_equal(reference_streams, got, "paged backend")


def test_streams_invariant_to_slot_count(engine_parts, reference_streams):
    """Per-lane refresh cadence: a lane refreshes on ITS OWN emitted
    token count, so co-scheduling width cannot shift selection."""
    got = run_and_collect(_spec(engine_parts, "xla", n_slots=3),
                          mixed_traffic(engine_parts[0]))
    assert_streams_equal(reference_streams, got, "n_slots=3")


def test_streams_invariant_to_replica_count(engine_parts,
                                            reference_streams):
    for n in (1, 2):
        got = run_and_collect(_spec(engine_parts, "xla", n_replicas=n),
                              mixed_traffic(engine_parts[0]))
        assert_streams_equal(reference_streams, got, f"replicas={n}")


def test_measured_window_flop_reduction(engine_parts):
    """gamma=0.5 topk pins every admitted lane at keep=G/2 groups, so the
    modeled FFN FLOP reduction of the whole measured window is exactly
    2x — admission seeding means no dense warm-in dilutes it."""
    streams, eng = run_and_collect(_spec(engine_parts, "xla"),
                                   mixed_traffic(engine_parts[0]),
                                   return_engine=True)
    st = eng.dsg_rt.flop_stats()
    assert st["flop_reduction_csr"] == pytest.approx(2.0)
    assert st["flop_reduction_bound"] == pytest.approx(2.0)
    assert st["steps"] == eng.steps


def test_ema_threshold_mode_runs_and_stays_sparse(engine_parts):
    """ema selection diverges from topk streams by design; the contract
    is that it drains the workload and every admitted lane keeps a
    non-degenerate pattern (>= 1, <= G groups)."""
    cfg, params, dsg = engine_parts
    spec = engine_spec(cfg.replace(dsg_ffn_apply="xla"), params, dsg,
                       dsg_serving=DSGServingConfig(refresh_interval=4,
                                                    threshold="ema"))
    streams, eng = run_and_collect(spec, mixed_traffic(cfg),
                                   return_engine=True)
    assert all(len(s) > 0 for s in streams.values())
    assert eng.dsg_rt.counts.min() >= 1
    assert eng.dsg_rt.counts.max() <= eng.dsg_rt.n_groups


# ---------------------------------------------------------------------------
# wiring guards
# ---------------------------------------------------------------------------

def test_sharded_executor_rejects_dsg(engine_parts):
    cfg, params, dsg = engine_parts
    with pytest.raises(NotImplementedError, match="dsg"):
        Router(cfg, params, dsg, n_replicas=2, exec_mode="sharded",
               n_slots=2, max_seq=64, prompt_bucket=32,
               dsg_serving=_DSG)


def test_check_bench_envelope_validation(tmp_path):
    """scripts/check_bench.py accepts the shared envelope and names the
    violation for each malformed variant (used via --root in CI-less
    runs; CI points it at the repo root)."""
    import importlib.util
    import json
    from pathlib import Path

    spec = importlib.util.spec_from_file_location(
        "check_bench",
        Path(__file__).resolve().parent.parent / "scripts"
        / "check_bench.py")
    cb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cb)

    good = {"name": "x",
            "gates": [{"description": "d", "threshold": 1.0,
                       "value": 2.0, "passed": True}],
            "ratio": 2.0,
            "timestamps": {"start": "2026-08-08T00:00:00+00:00",
                           "end": "2026-08-08T00:00:05+00:00"},
            "results": {}}

    def write(payload):
        p = tmp_path / "BENCH_x.json"
        p.write_text(json.dumps(payload))
        return p

    assert cb.check_file(write(good)) == []
    for mutate, needle in (
            (lambda d: d.pop("ratio"), "missing"),
            (lambda d: d.update(extra=1), "unexpected top-level"),
            (lambda d: d.update(gates=[]), "non-empty"),
            (lambda d: d["gates"][0].update(passed=False), "FAILED"),
            (lambda d: d["timestamps"].update(end="2026-08-07T23:00:00"),
             "end < start"),
            (lambda d: d.update(name=""), "non-empty string")):
        payload = json.loads(json.dumps(good))
        mutate(payload)
        problems = cb.check_file(write(payload))
        assert problems and any(needle in p for p in problems), (
            needle, problems)


def test_engine_validation_raises(engine_parts):
    cfg, params, dsg = engine_parts
    kw = dict(n_slots=2, max_seq=64, prompt_bucket=32)
    with pytest.raises(ValueError, match="enabled"):
        ServingEngine(cfg.replace(dsg=cfg.dsg._replace(enabled=False)),
                      params, None, dsg_serving=True, **kw)
    with pytest.raises(ValueError, match="SwiGLU"):
        ServingEngine(cfg.replace(moe_experts=4, moe_topk=2), params,
                      dsg, dsg_serving=True, **kw)
    with pytest.raises(ValueError, match="relu_sum"):
        ServingEngine(cfg.replace(dsg=cfg.dsg._replace(score="abs_sum")),
                      params, dsg, dsg_serving=True, **kw)


@pytest.mark.parametrize("chunk", CHUNK_AXIS)
def test_dsg_streams_invariant_to_decode_chunk(engine_parts, chunk):
    """DSG-gated decode under the fused chunk loop: DRS refresh must
    land on chunk boundaries (refresh_interval 8 divides both chunk
    sizes), and streams must match the unchunked DSG engine
    bit-for-bit."""
    cfg = engine_parts[0]
    kw = dict(dsg_serving=DSGServingConfig(refresh_interval=8))
    ref = run_and_collect(engine_spec(*engine_parts, **kw),
                          mixed_traffic(cfg))
    out = run_and_collect(
        engine_spec(*engine_parts, decode_chunk=chunk, **kw),
        mixed_traffic(cfg), max_steps=1000)
    assert_streams_equal(ref, out, f"dsg decode_chunk={chunk}")
