"""Unit tests for the pluggable KV-cache backend layer
(serving/kv_cache.py): block allocator alloc/free/reuse and out-of-pages
behaviour, CacheHandle pytree round-trips, and paged-backend page-table /
reservation bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import smoke_cfg
from repro.models import api
from repro.serving.kv_cache import (NULL_PAGE, BlockAllocator, CacheHandle,
                                    OutOfPages, get_backend)


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_reuse():
    a = BlockAllocator(8, reserved=1)          # ids 1..7 allocatable
    assert a.free_pages == 7
    p1 = a.alloc(3)
    assert len(p1) == len(set(p1)) == 3
    assert all(1 <= p < 8 for p in p1)         # scratch id 0 never issued
    p2 = a.alloc(4)
    assert a.free_pages == 0
    assert not set(p1) & set(p2)
    a.free(p1)
    assert a.free_pages == 3
    p3 = a.alloc(3)
    assert set(p3) == set(p1)                  # freed pages are reused

def test_allocator_out_of_pages_and_bad_frees():
    a = BlockAllocator(4, reserved=1)
    pages = a.alloc(3)
    with pytest.raises(OutOfPages):
        a.alloc(1)
    a.free(pages[:1])
    with pytest.raises(ValueError):            # double free
        a.free(pages[:1])
    with pytest.raises(ValueError):            # never-allocated id
        a.free([0])
    assert a.free_pages == 1

def test_allocator_needs_allocatable_pages():
    with pytest.raises(ValueError):
        BlockAllocator(1, reserved=1)


# ---------------------------------------------------------------------------
# CacheHandle pytree
# ---------------------------------------------------------------------------

def test_cache_handle_pytree_roundtrip():
    h = CacheHandle({"k": jnp.zeros((2, 3))}, "paged", 8)
    leaves, treedef = jax.tree_util.tree_flatten(h)
    h2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert h2.kind == "paged" and h2.page_size == 8
    h3 = jax.jit(lambda x: x)(h)               # static aux survives jit
    assert h3.kind == "paged" and h3.page_size == 8
    np.testing.assert_array_equal(np.asarray(h3.data["k"]),
                                  np.asarray(h.data["k"]))


# ---------------------------------------------------------------------------
# paged backend bookkeeping
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cfg():
    return smoke_cfg()


def test_paged_backend_write_grow_free(cfg):
    be = get_backend("paged", page_size=8, total_tokens=64)   # 8 pages
    h = be.make(cfg, 2, 32)
    assert h.kind == "paged" and h.page_size == 8
    table = np.asarray(h.data["page_table"])
    assert table.shape == (2, 4) and (table == NULL_PAGE).all()

    lane = api.make_cache(cfg, 1, 32)
    h = be.write(h, lane, 0, n_tokens=12, reserve_tokens=20)
    row = be._table[0]
    assert (row[:2] != NULL_PAGE).all() and (row[2:] == NULL_PAGE).all()
    assert be.allocator.free_pages == 6
    # reservation: ceil(20/8)=3 pages total, 2 allocated -> 1 outstanding
    assert int(be._resv[0]) == 1
    assert be.can_admit(40)                    # 5 <= 6 - 1
    assert not be.can_admit(41)                # 6 > 6 - 1

    h = be.ensure(h, 0, 16)                    # page for position 16
    assert be._table[0, 2] != NULL_PAGE
    assert int(be._resv[0]) == 0 and be.allocator.free_pages == 5
    h2 = be.ensure(h, 0, 17)                   # already mapped -> no-op
    assert h2 is h

    h = be.free(h, 0)
    assert (be._table[0] == NULL_PAGE).all()
    assert be.allocator.free_pages == 8
    assert (np.asarray(h.data["page_table"])[0] == NULL_PAGE).all()


def test_paged_backend_guards(cfg):
    be = get_backend("paged", page_size=8)
    with pytest.raises(ValueError):            # max_seq not page-aligned
        be.make(cfg, 2, 30)
    be2 = get_backend("paged", page_size=8)
    h = be2.make(cfg, 2, 32)
    with pytest.raises(RuntimeError):          # one live handle per backend
        be2.make(cfg, 2, 32)
    with pytest.raises(ValueError):            # paged write needs n_tokens
        be2.write(h, api.make_cache(cfg, 1, 32), 0)
    with pytest.raises(ValueError):
        get_backend("ring")


def test_backend_resident_bytes(cfg):
    dense = get_backend("dense")
    hd = dense.make(cfg, 4, 256)
    paged = get_backend("paged", page_size=16, total_tokens=4 * 96)
    hp = paged.make(cfg, 4, 256)
    assert dense.resident_bytes(hd) >= 2 * paged.resident_bytes(hp)
