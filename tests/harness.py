"""Shared differential-test harness for the serving stack.

The equivalence surface grown across PRs 1-3 (dense vs paged backends,
XLA vs Pallas decode executors, overlap vs wave admission — and now
1 vs N router replicas) all reduces to the same check: drive the same
requests through two configurations and compare the per-request greedy
token streams.  This module is that check, extracted from the copies
that used to live in test_paged_attention.py / test_serving_overlap.py /
test_kv_cache.py:

    parts = make_engine_parts()                  # (cfg, params, dsg)
    reqs  = mixed_traffic(parts[0])              # deterministic traffic
    a = run_and_collect(engine_spec(*parts), reqs)
    b = run_and_collect(engine_spec(*parts, cache_backend="paged",
                                    page_size=8, cache_tokens=80),
                        mixed_traffic(parts[0]))
    assert_streams_equal(a, b)

`run_and_collect` takes an "engine spec" dict (cfg/params/dsg plus any
`ServingEngine` kwargs; add `n_replicas`/`policy` — and optionally
`exec_mode`/`mesh`, forwarded to the replica executor — to run through
the front-end `Router` instead) and returns `{rid: tokens}`.  Traffic
helpers draw from a fixed-seed generator, so two calls with the same
seed produce identical prompts in fresh Request objects — never reuse a
Request across runs; its `output` list is engine state.
"""
import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serving.router import Router
from repro.serving.scheduler import Request, ServingEngine

SMOKE_ARCH = "internlm2-1.8b"

# The decode_chunk axis every differential suite pins: 1 is the
# classic one-token-per-step loop, 8 is the fused device-resident
# chunk (PR 9).  Parametrizing over this pair catches chunk-boundary
# bugs (commit_chunk early-exit, EOS mid-chunk) in every suite that
# adopts it without bespoke engine setup.
CHUNK_AXIS = (1, 8)


def smoke_cfg(arch: str = SMOKE_ARCH, threshold_mode: str = None):
    """The smoke-model config the serving tests share; threshold_mode
    "topk" (per-row DRS selection) makes lanes computationally
    independent, which every bitwise stream comparison relies on — the
    default "shared" mode couples all lanes to row 0's scores by
    design (the paper's Appendix B inter-sample threshold sharing)."""
    cfg = configs.get_smoke_config(arch)
    if threshold_mode is not None:
        cfg = cfg.replace(dsg=cfg.dsg._replace(
            threshold_mode=threshold_mode))
    return cfg


def make_engine_parts(arch: str = SMOKE_ARCH,
                      threshold_mode: str = "topk"):
    """(cfg, params, dsg) for a smoke model, deterministic across calls."""
    cfg = smoke_cfg(arch, threshold_mode)
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)
    return cfg, params, dsg


def engine_spec(cfg, params, dsg, **engine_kw) -> dict:
    """Bundle model parts + engine kwargs into the spec run_and_collect
    consumes.  Defaults match the historical serving-test engines
    (2 slots, max_seq 64, prompt bucket 32, overlap admission)."""
    spec = {"cfg": cfg, "params": params, "dsg": dsg,
            "n_slots": 2, "max_seq": 64, "prompt_bucket": 32,
            "admission": "overlap"}
    spec.update(engine_kw)
    return spec


def mixed_traffic(cfg, *, seed=23, n=6, temperature: float = 0.0,
                  top_p: float = 1.0):
    """The serving tests' canonical mixed traffic: n requests with
    prompt lengths in [4, 30) and generation budgets in [3, 9), drawn in
    the exact rng order the pre-extraction copies used, so refactored
    tests exercise the same token streams."""
    rng = np.random.default_rng(seed)
    return [Request(uid=u,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 30)),
                                        dtype=np.int32),
                    max_new=int(rng.integers(3, 9)),
                    temperature=temperature, top_p=top_p)
            for u in range(n)]


def shared_prefix_traffic(cfg, *, seed=29, n=6, prompt_len=24,
                          prefix_len=16, max_new=6,
                          temperature: float = 0.0, top_p: float = 1.0):
    """n requests sharing one random prefix with per-request random
    suffixes — the canonical traffic for prefix-sharing differentials.
    Prompt lengths are FIXED (not mixed): the paged backend only shares
    pages between identical padded rows, so every request must land in
    the same prompt bucket with the prefix at the same offset."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, prefix_len, dtype=np.int32)
    reqs = []
    for u in range(n):
        suffix = rng.integers(0, cfg.vocab, prompt_len - prefix_len,
                              dtype=np.int32)
        reqs.append(Request(uid=u,
                            prompt=np.concatenate([prefix, suffix]),
                            max_new=max_new, temperature=temperature,
                            top_p=top_p))
    return reqs


def run_and_collect(spec: dict, requests, *, max_steps: int = 400,
                    return_engine: bool = False, faults=None):
    """Run `requests` through the engine (or router) the spec describes
    and return `{rid: tokens}` — every submitted request must finish
    within max_steps.  Set `n_replicas` (and optionally `policy`) in the
    spec to run a Router; otherwise a bare ServingEngine.  With
    return_engine=True, returns (streams, engine_or_router) for
    allocator / counter assertions.  `faults` takes a list of
    ReplicaFault specs to attach via ServingFaultInjector before the
    run (chaos cases; pair with a `fault_tolerance` spec entry)."""
    kw = dict(spec)
    cfg, params, dsg = kw.pop("cfg"), kw.pop("params"), kw.pop("dsg")
    n_replicas = kw.pop("n_replicas", None)
    policy = kw.pop("policy", "least_queue")
    if n_replicas is None:
        eng = ServingEngine(cfg, params, dsg, **kw)
    else:
        eng = Router(cfg, params, dsg, n_replicas=n_replicas,
                     policy=policy, **kw)
    if faults:
        from repro.runtime.fault_tolerance import ServingFaultInjector
        inj = ServingFaultInjector(list(faults))
        inj.attach(eng.engines if n_replicas is not None else [eng])
    for r in requests:
        eng.submit(r)
    try:
        done = eng.run(max_steps=max_steps)
    finally:
        if n_replicas is not None and not return_engine:
            eng.close()
    assert len(done) == len(requests), (
        f"only {len(done)} of {len(requests)} requests finished "
        f"within {max_steps} steps")
    assert all(r.status == "ok" for r in done.values()), (
        "non-ok request in " +
        str({u: r.status for u, r in done.items() if r.status != "ok"}))
    streams = {u: list(r.output) for u, r in done.items()}
    return (streams, eng) if return_engine else streams


def assert_streams_equal(expected: dict, actual: dict, context: str = ""):
    """Per-request token streams must match exactly (uid-keyed, so the
    comparison is permutation-free by construction)."""
    tag = f" [{context}]" if context else ""
    assert set(expected) == set(actual), (
        f"request id sets differ{tag}: "
        f"{sorted(expected)} vs {sorted(actual)}")
    for uid in sorted(expected):
        assert expected[uid] == actual[uid], (
            f"token stream for request {uid} diverges{tag}: "
            f"{expected[uid]} vs {actual[uid]}")
