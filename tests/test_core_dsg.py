"""Core DSG tests: projection statistics, JLL preservation, DRS selection,
mask algebra, double-mask norm compatibility."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt); skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import double_mask, drs, masks, projection
from repro.core import dsg_linear as dl


# ---------------------------------------------------------------------------
# sparse random projection (paper Eq. 5-6)
# ---------------------------------------------------------------------------

def test_projection_ternary_distribution():
    r = projection.make_projection(jax.random.PRNGKey(0), 256, 512, s=3)
    vals = np.unique(np.round(np.asarray(r) * np.sqrt(256), 5))
    # {-sqrt(3), 0, +sqrt(3)} only
    assert len(vals) == 3
    np.testing.assert_allclose(sorted(abs(v) for v in vals)[1:],
                               [np.sqrt(3)] * 2, rtol=1e-5)
    zero_frac = float((np.asarray(r) == 0).mean())
    assert 0.60 < zero_frac < 0.73          # 1 - 1/s = 2/3


def test_jll_dim_monotone_in_eps():
    k_tight = projection.jll_dim(4096, 1000, eps=0.3)
    k_loose = projection.jll_dim(4096, 1000, eps=0.9)
    assert k_tight >= k_loose
    assert k_tight % projection.LANE == 0
    assert k_loose >= projection.LANE


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_jll_inner_product_preservation(seed):
    """Paper Eq. (4)/(15): |<f(x), f(w)> - <x, w>| <= eps/2 (|x|^2+|w|^2)
    with high probability.  We check the median error over pairs is well
    inside the bound for eps=0.5."""
    key = jax.random.PRNGKey(seed)
    d, n, eps = 512, 64, 0.5
    k = projection.jll_dim(d, n, eps)
    kx, kw, kr = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kw, (d, n))
    r = projection.make_projection(kr, k, d)
    fx = projection.project_rows(r, x)
    fw = projection.project(r, w)
    true = x @ w
    approx = fx @ fw
    bound = 0.5 * eps * (jnp.sum(x * x, -1)[:, None]
                         + jnp.sum(w * w, 0)[None, :])
    viol = jnp.abs(approx - true) > bound
    assert float(viol.mean()) < 0.05        # 1 - O(eps^2) probability


def test_norm_preservation():
    key = jax.random.PRNGKey(3)
    d, k = 1024, 256
    z = jax.random.normal(key, (128, d))
    r = projection.make_projection(jax.random.PRNGKey(4), k, d)
    fz = projection.project_rows(r, z)
    ratio = jnp.linalg.norm(fz, axis=-1) / jnp.linalg.norm(z, axis=-1)
    assert float(jnp.median(jnp.abs(ratio - 1.0))) < 0.15


# ---------------------------------------------------------------------------
# DRS selection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gamma", [0.25, 0.5, 0.75])
def test_topk_mask_exact_density(gamma):
    cfg = drs.DRSConfig(gamma=gamma, block=32, threshold_mode="topk")
    scores = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    mask, _ = drs.select_mask(scores, 512, cfg)
    k = drs.keep_groups(512, cfg)
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), k)


def test_drs_matches_oracle_on_separated_scores():
    """When the weight columns have very different magnitudes, DRS must
    reproduce the oracle selection (the paper's Fig 5(c) claim)."""
    key = jax.random.PRNGKey(1)
    d, f, block = 512, 1024, 64
    scales = jnp.repeat(2.0 ** jnp.arange(f // block), block)
    w = jax.random.normal(key, (d, f)) * scales / np.sqrt(d)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (32, d)))
    cfg = drs.DRSConfig(gamma=0.5, block=block)
    k = projection.jll_dim(d, f, 0.5)
    r = projection.make_projection(jax.random.PRNGKey(3), k, d)
    fx = projection.project_rows(r, x)
    fw = projection.project(r, w)
    m_drs, _ = drs.drs_mask(fx, fw, cfg)
    m_oracle = drs.oracle_mask(x @ w, f, cfg)
    agreement = float((m_drs == m_oracle).mean())
    assert agreement > 0.95


def test_shared_threshold_mode():
    cfg = drs.DRSConfig(gamma=0.5, block=32, threshold_mode="shared")
    scores = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    mask, _ = drs.select_mask(scores, 256, cfg)
    # row 0 keeps exactly k groups (its own threshold)
    assert int(mask[0].sum()) == drs.keep_groups(256, cfg)
    assert mask.shape == scores.shape


def test_ema_threshold_updates():
    cfg = drs.DRSConfig(gamma=0.5, block=32, threshold_mode="ema",
                        ema_decay=0.5)
    scores = jnp.ones((4, 8)) * jnp.arange(8)
    _, ema1 = drs.select_mask(scores, 256, cfg, ema_threshold=jnp.float32(0))
    _, ema2 = drs.select_mask(scores, 256, cfg, ema_threshold=ema1)
    assert float(ema2) > float(ema1) >= 0.0


def test_mask_is_constant_wrt_autodiff():
    cfg = dl.DSGConfig(enabled=True, gamma=0.5, block=64)
    p = dl.init_swiglu(jax.random.PRNGKey(0), 128, 256)
    state = dl.init_dsg_state(jax.random.PRNGKey(1), 128, 256, cfg,
                              dl.search_weight(p))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 128))

    def loss(x_):
        return jnp.sum(dl.swiglu_ffn(p, x_, state, cfg) ** 2)

    g = jax.grad(loss)(x)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_backward_sparsity():
    """Algorithm 1: gradients of dropped neuron groups are exactly zero in
    w_down rows and gate/up columns."""
    cfg = dl.DSGConfig(enabled=True, gamma=0.5, block=64)
    p = dl.init_swiglu(jax.random.PRNGKey(0), 128, 256)
    state = dl.init_dsg_state(jax.random.PRNGKey(1), 128, 256, cfg,
                              dl.search_weight(p))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 128))
    mask = dl.drs_group_mask(x, state, cfg)            # (8, 4)
    dropped_everywhere = np.where(np.asarray(mask.max(0)) == 0)[0]
    g = jax.grad(lambda p_: jnp.sum(
        dl.swiglu_ffn(p_, x, state, cfg) ** 2))(p)
    gd = np.asarray(g["w_down"]).reshape(4, 64, 128)
    for gidx in dropped_everywhere:
        np.testing.assert_array_equal(gd[gidx], 0.0)


# ---------------------------------------------------------------------------
# double-mask (paper §2.3)
# ---------------------------------------------------------------------------

def test_double_mask_restores_sparsity_after_bn():
    key = jax.random.PRNGKey(0)
    b, f, block = 64, 256, 32
    x = jax.nn.relu(jax.random.normal(key, (b, f)))
    gmask = (jax.random.uniform(jax.random.PRNGKey(1),
                                (b, f // block)) > 0.5).astype(jnp.float32)
    scale = jnp.ones((f,)) * 1.3
    bias = jnp.ones((f,)) * 0.1              # shift makes zeros non-zero

    def bn(z):
        return double_mask.batch_norm_train(z, scale, bias)

    single = double_mask.single_mask(bn, x, gmask, block)
    dble = double_mask.double_mask(bn, x, gmask, block)
    exp = np.asarray(drs.expand_mask(gmask, block))
    # single mask: BN bias densifies the masked-out positions
    assert (np.asarray(single)[exp == 0] != 0).mean() > 0.9
    # double mask: fully sparse dataflow restored
    np.testing.assert_array_equal(np.asarray(dble)[exp == 0], 0.0)


def test_double_mask_preserves_kept_values():
    """BN is monotone per-channel: the kept activations under the double
    mask equal BN applied to the masked input (no distortion)."""
    key = jax.random.PRNGKey(5)
    b, f, block = 32, 128, 16
    x = jax.random.normal(key, (b, f))
    gmask = jnp.ones((b, f // block))

    def bn(z):
        return double_mask.batch_norm_train(z, jnp.ones(f), jnp.zeros(f))

    out = double_mask.double_mask(bn, x, gmask, block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(bn(x)),
                               rtol=1e-5, atol=1e-6)


def test_mask_overhead_under_2pct():
    """Paper §3.3: selection-mask memory overhead < 2%."""
    shape = (64, 4096, 14336)
    dense = int(np.prod(shape)) * 2
    overhead = masks.mask_overhead_bytes(shape, 128)
    assert overhead / dense < 0.02
