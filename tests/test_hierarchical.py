"""Hierarchical compressed gradient reduction: multi-device shard_map test
(subprocess with 8 host devices arranged as pod=2 x data=4)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.optim.hierarchical import hierarchical_grad_reduce

    mesh = make_mesh((2, 4), ("pod", "data"))
    key = jax.random.PRNGKey(0)
    n, dim = 8, 64
    gs = jax.random.normal(key, (n, dim))          # one grad per shard

    def step(g, err):
        return hierarchical_grad_reduce(g, err)

    f = jax.jit(shard_map(step, mesh=mesh,
                          in_specs=(P(("pod", "data")),
                                    P(("pod", "data"))),
                          out_specs=(P(("pod", "data")),
                                     P(("pod", "data")))))

    # exact reference: fleet mean
    exact = jnp.broadcast_to(gs.mean(0, keepdims=True), gs.shape)

    # (a) uncompressed path == exact
    f0 = jax.jit(shard_map(
        lambda g, e: hierarchical_grad_reduce(g, e, compress=False),
        mesh=mesh, in_specs=(P(("pod", "data")), P(("pod", "data"))),
        out_specs=(P(("pod", "data")), P(("pod", "data")))))
    out0, _ = f0(gs.reshape(n, dim), jnp.zeros((n, dim)))
    np.testing.assert_allclose(np.asarray(out0), np.asarray(exact),
                               rtol=1e-5, atol=1e-6)
    print("UNCOMPRESSED_OK")

    # (b) compressed + error feedback: telescoping sum converges to the
    # exact gradient sum over repeated steps with a FIXED gradient
    err = jnp.zeros((n, dim))
    acc = jnp.zeros((n, dim))
    for _ in range(30):
        dec, err = f(gs, err)
        acc = acc + dec
    mean_step = acc / 30
    rel = float(jnp.linalg.norm(mean_step - exact)
                / jnp.linalg.norm(exact))
    assert rel < 0.05, rel
    print("COMPRESSED_OK", rel)
""")


def test_hierarchical_reduce_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "UNCOMPRESSED_OK" in r.stdout, r.stdout + r.stderr
    assert "COMPRESSED_OK" in r.stdout, r.stdout + r.stderr
