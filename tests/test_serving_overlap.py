"""Overlap-admission isolation: splicing a new prompt into a free slot must
leave resident slots' K/V bytes and outputs bit-identical to a solo run.

Uses threshold_mode="topk" (per-row DRS selection) so lanes are
computationally independent — the smoke default "shared" mode implements
the paper's Appendix B inter-sample threshold sharing, which deliberately
couples every lane to lane 0's scores; that coupling is a property of the
selection rule, not of the engine's cache surgery, so it is pinned off
here."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serving.scheduler import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_parts():
    cfg = configs.get_smoke_config("internlm2-1.8b")
    cfg = cfg.replace(dsg=cfg.dsg._replace(threshold_mode="topk"))
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)
    return cfg, params, dsg


def _make_engine(cfg, params, dsg):
    return ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                         prompt_bucket=32, admission="overlap")


def _solo_output(cfg, params, dsg, req_proto):
    eng = _make_engine(cfg, params, dsg)
    eng.submit(Request(uid=0, prompt=req_proto.prompt,
                       max_new=req_proto.max_new))
    return eng.run(max_steps=200)[0].output


def test_admission_leaves_resident_slot_untouched(engine_parts):
    cfg, params, dsg = engine_parts
    rng = np.random.default_rng(7)
    req_a = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 12,
                                               dtype=np.int32), max_new=10)
    req_b = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 20,
                                               dtype=np.int32), max_new=8)
    solo_a = _solo_output(cfg, params, dsg, req_a)
    solo_b = _solo_output(cfg, params, dsg, req_b)
    assert len(solo_a) == 10 and len(solo_b) == 8

    # mixed run: A decodes alone for 3 steps, then B is admitted into the
    # free slot while A keeps going
    eng = _make_engine(cfg, params, dsg)
    eng.submit(Request(uid=0, prompt=req_a.prompt, max_new=10))
    for _ in range(3):
        eng.step()
    assert len(eng.slots[0].req.output) == 3 and eng.slots[1].free

    lane0_before = {k: np.array(v[:, 0]) for k, v in eng.cache.items()}
    eng.submit(Request(uid=1, prompt=req_b.prompt, max_new=8))
    eng._admit()                      # splice B into slot 1, nothing else
    assert not eng.slots[1].free
    # admission performed cache surgery on lane 1 only: lane 0's K/V bytes
    # are bit-identical, lane 1's actually changed
    for k, v in eng.cache.items():
        np.testing.assert_array_equal(lane0_before[k], np.array(v[:, 0]))
    assert any(not np.array_equal(np.zeros_like(np.array(v[:, 1])),
                                  np.array(v[:, 1]))
               for v in eng.cache.values())

    done = eng.run(max_steps=200)
    # both sequences are bit-identical to their solo runs: admission never
    # perturbed the resident lane, and the per-lane position/RoPE state of
    # the admitted lane is honest despite entering mid-decode
    assert done[0].output == solo_a
    assert done[1].output == solo_b


def test_staggered_stream_matches_solo_runs(engine_parts):
    """Continuous traffic: 6 requests of assorted lengths trickle through 2
    slots; every request's greedy output must equal its solo-run output."""
    cfg, params, dsg = engine_parts
    rng = np.random.default_rng(11)
    reqs = [Request(uid=u, prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(4, 30)),
                                               dtype=np.int32),
                    max_new=int(rng.integers(3, 9))) for u in range(6)]
    solo = {r.uid: _solo_output(cfg, params, dsg, r) for r in reqs}

    eng = _make_engine(cfg, params, dsg)
    it = iter(reqs)
    eng.submit(next(it))
    pending = True
    while pending or any(not s.free for s in eng.slots) or eng.queue:
        # drip-feed: submit the next request every other step so admissions
        # land mid-decode, not in a fresh batch
        if pending and eng.steps % 2 == 0:
            nxt = next(it, None)
            if nxt is None:
                pending = False
            else:
                eng.submit(nxt)
        eng.step()
        assert eng.steps < 500
    for r in reqs:
        assert eng.done[r.uid].output == solo[r.uid], r.uid
