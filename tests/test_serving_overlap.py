"""Overlap-admission isolation: splicing a new prompt into a free slot must
leave resident slots' K/V bytes and outputs bit-identical to a solo run,
and the paged cache backend must reproduce the dense engine exactly over
admit -> decode -> retire -> readmit sequences.

Uses threshold_mode="topk" (per-row DRS selection) so lanes are
computationally independent — the smoke default "shared" mode implements
the paper's Appendix B inter-sample threshold sharing, which deliberately
couples every lane to lane 0's scores; that coupling is a property of the
selection rule, not of the engine's cache surgery, so it is pinned off
here."""
import numpy as np
import pytest

from harness import (assert_streams_equal, engine_spec, make_engine_parts,
                     mixed_traffic, run_and_collect)
from repro.serving.scheduler import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_parts():
    return make_engine_parts()


def _make_engine(cfg, params, dsg):
    return ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                         prompt_bucket=32, admission="overlap")


def _solo_output(cfg, params, dsg, req_proto):
    eng = _make_engine(cfg, params, dsg)
    eng.submit(Request(uid=0, prompt=req_proto.prompt,
                       max_new=req_proto.max_new))
    return eng.run(max_steps=200)[0].output


def test_admission_leaves_resident_slot_untouched(engine_parts):
    cfg, params, dsg = engine_parts
    rng = np.random.default_rng(7)
    req_a = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 12,
                                               dtype=np.int32), max_new=10)
    req_b = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 20,
                                               dtype=np.int32), max_new=8)
    solo_a = _solo_output(cfg, params, dsg, req_a)
    solo_b = _solo_output(cfg, params, dsg, req_b)
    assert len(solo_a) == 10 and len(solo_b) == 8

    # mixed run: A decodes alone for 3 steps, then B is admitted into the
    # free slot while A keeps going
    eng = _make_engine(cfg, params, dsg)
    eng.submit(Request(uid=0, prompt=req_a.prompt, max_new=10))
    for _ in range(3):
        eng.step()
    assert len(eng.slots[0].req.output) == 3 and eng.slots[1].free

    lane0_before = {k: np.array(v[:, 0]) for k, v in eng.cache.data.items()}
    eng.submit(Request(uid=1, prompt=req_b.prompt, max_new=8))
    eng._admit()                      # splice B into slot 1, nothing else
    assert not eng.slots[1].free
    # admission performed cache surgery on lane 1 only: lane 0's K/V bytes
    # are bit-identical, lane 1's actually changed
    for k, v in eng.cache.data.items():
        np.testing.assert_array_equal(lane0_before[k], np.array(v[:, 0]))
    assert any(not np.array_equal(np.zeros_like(np.array(v[:, 1])),
                                  np.array(v[:, 1]))
               for v in eng.cache.data.values())

    done = eng.run(max_steps=200)
    # both sequences are bit-identical to their solo runs: admission never
    # perturbed the resident lane, and the per-lane position/RoPE state of
    # the admitted lane is honest despite entering mid-decode
    assert done[0].output == solo_a
    assert done[1].output == solo_b


def test_staggered_stream_matches_solo_runs(engine_parts):
    """Continuous traffic: 6 requests of assorted lengths trickle through 2
    slots; every request's greedy output must equal its solo-run output."""
    cfg, params, dsg = engine_parts
    rng = np.random.default_rng(11)
    reqs = [Request(uid=u, prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(4, 30)),
                                               dtype=np.int32),
                    max_new=int(rng.integers(3, 9))) for u in range(6)]
    solo = {r.uid: _solo_output(cfg, params, dsg, r) for r in reqs}

    eng = _make_engine(cfg, params, dsg)
    it = iter(reqs)
    eng.submit(next(it))
    pending = True
    while pending or any(not s.free for s in eng.slots) or eng.queue:
        # drip-feed: submit the next request every other step so admissions
        # land mid-decode, not in a fresh batch
        if pending and eng.steps % 2 == 0:
            nxt = next(it, None)
            if nxt is None:
                pending = False
            else:
                eng.submit(nxt)
        eng.step()
        assert eng.steps < 500
    for r in reqs:
        assert eng.done[r.uid].output == solo[r.uid], r.uid


# ---------------------------------------------------------------------------
# paged backend equivalence (admit -> decode -> retire -> readmit)
# ---------------------------------------------------------------------------

def test_paged_stream_matches_dense_bitwise(engine_parts):
    """6 requests through 2 slots: every lane is retired and readmitted,
    pages are allocated, freed, and reused — and every request's output is
    bit-identical to the dense engine's (same attention shapes, same
    values at positions < pos, everything else masked)."""
    spec = engine_spec(*engine_parts)
    dense_out = run_and_collect(spec, mixed_traffic(spec["cfg"]))
    # worst-case lane reservation: min(bucket 32 + max_new 8, 64) = 40
    # tokens = 5 pages; 2 lanes -> 80-token pool (vs dense 2 * 64 = 128)
    paged_out, paged_eng = run_and_collect(
        engine_spec(*engine_parts, cache_backend="paged", page_size=8,
                    cache_tokens=80),
        mixed_traffic(spec["cfg"]), return_engine=True)
    assert_streams_equal(dense_out, paged_out, "paged vs dense")
    # every page returned to the free list after the stream drains
    alloc = paged_eng.backend.allocator
    assert alloc.free_pages == alloc.n_pages - alloc.reserved


def test_paged_resident_bytes_smaller(engine_parts):
    cfg = engine_parts[0]
    _, dense_eng = run_and_collect(engine_spec(*engine_parts),
                                   mixed_traffic(cfg, n=2),
                                   return_engine=True)
    _, paged_eng = run_and_collect(
        engine_spec(*engine_parts, cache_backend="paged", page_size=8,
                    cache_tokens=80),
        mixed_traffic(cfg, n=2), return_engine=True)
    dense_b = dense_eng.backend.resident_bytes(dense_eng.cache)
    paged_b = paged_eng.backend.resident_bytes(paged_eng.cache)
    assert paged_b < dense_b


def test_paged_matches_dense_under_sampling(engine_parts):
    """Sampling goes through identical logits on both backends, and the
    PRNG key schedule depends only on (engine seed, step, lane) — so
    sampled streams must agree token-for-token too."""
    cfg = engine_parts[0]
    kw = dict(temperature=0.8, top_p=0.9)
    dense_out = run_and_collect(engine_spec(*engine_parts, seed=7),
                                mixed_traffic(cfg, n=4, **kw))
    paged_out = run_and_collect(
        engine_spec(*engine_parts, seed=7, cache_backend="paged",
                    page_size=8, cache_tokens=80),
        mixed_traffic(cfg, n=4, **kw))
    assert_streams_equal(dense_out, paged_out, "sampled paged vs dense")


def test_paged_pool_for_one_lane_defers_admission(engine_parts):
    """A pool that can only hold one request's reservation serialises
    admissions instead of corrupting or crashing: both requests finish
    with their solo outputs."""
    cfg, params, dsg = engine_parts
    reqs = mixed_traffic(cfg, n=2)
    solo = {r.uid: _solo_output(cfg, params, dsg, r) for r in reqs}
    # one lane's reservation is 5 pages of 8; 6 pages can't fit two lanes
    out, eng = run_and_collect(
        engine_spec(*engine_parts, cache_backend="paged", page_size=8,
                    cache_tokens=48),
        mixed_traffic(cfg, n=2), return_engine=True)
    assert_streams_equal(solo, out, "deferred admissions vs solo")
    assert eng.steps > 0
