"""Front-end router over per-replica serving engines (serving/router.py).

The load-bearing invariant: each replica is solo-deterministic under
per-row DRS selection, so merged greedy token streams keyed by request
uid must be IDENTICAL for 1, 2, and 3 replicas, across {dense, paged}
cache backends and {round_robin, least_queue} routing policies — routing
decides only WHERE a request decodes, never WHAT it decodes.  On top of
that: a single-replica router is bit-identical to a bare ServingEngine
(greedy and sampled), and the least_pages policy never dispatches a
request to a replica whose paged pool cannot reserve its worst-case page
count (so per-replica admission deferral never triggers)."""
import numpy as np
import pytest

from harness import (CHUNK_AXIS, assert_streams_equal, engine_spec,
                     make_engine_parts, mixed_traffic, run_and_collect)
from repro.serving.kv_cache import DenseBackend
from repro.serving.router import Router, get_policy
from repro.serving.scheduler import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_parts():
    return make_engine_parts()


_BACKEND_KW = {
    "dense": {},
    # worst-case lane reservation: min(bucket 32 + max_new 8, 64) = 40
    # tokens = 5 pages of 8; 80-token pools hold two lanes per replica
    "paged": {"cache_backend": "paged", "page_size": 8, "cache_tokens": 80},
}

# module-level memo: the 1-replica reference stream per backend, shared
# across the invariance parametrizations so it is computed once
_baseline = {}


def _reference(engine_parts, backend):
    if backend not in _baseline:
        spec = engine_spec(*engine_parts, **_BACKEND_KW[backend])
        _baseline[backend] = run_and_collect(spec,
                                             mixed_traffic(spec["cfg"]))
    return _baseline[backend]


# ---------------------------------------------------------------------------
# construction / policy guards (no engine runs — cheap)
# ---------------------------------------------------------------------------

def test_policy_and_constructor_guards(engine_parts):
    cfg, params, dsg = engine_parts
    with pytest.raises(ValueError):
        get_policy("fastest")
    with pytest.raises(ValueError):
        Router(cfg, params, dsg, n_replicas=0)
    with pytest.raises(ValueError):                 # backend instances are
        Router(cfg, params, dsg, n_replicas=2,      # one-handle objects
               cache_backend=DenseBackend())
    with pytest.raises(ValueError):                 # one view per replica
        Router(cfg, params, dsg, n_replicas=2, param_views=[params])


def test_stats_raise_before_any_finish(engine_parts):
    cfg, params, dsg = engine_parts
    router = Router(cfg, params, dsg, n_replicas=2, n_slots=2, max_seq=64)
    with pytest.raises(ValueError):
        router.throughput()
    assert router.drain() == {}        # nothing queued: drains to nothing


def test_introspection_counters(engine_parts):
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=3, max_seq=64,
                        prompt_bucket=32, cache_backend="paged",
                        page_size=8, cache_tokens=80)
    assert eng.queue_depth() == 0 and eng.free_slots() == 3
    assert eng.busy_slots() == 0
    assert eng.free_pages() == eng.backend.allocator.free_pages == 10
    req = Request(uid=0, prompt=np.zeros(12, np.int32), max_new=8)
    eng.submit(req)
    assert eng.queue_depth() == 1
    # bucket_for(12) = 16; min(16 + 8, 64) = 24 tokens -> 3 pages of 8
    assert eng.pages_needed(req) == 3
    assert eng.can_admit_request(req)
    done = eng.drain(max_steps=50)     # retirement draining empties it all
    assert len(done) == 1 and eng.queue_depth() == 0
    assert eng.free_slots() == 3
    assert eng.free_pages() == eng.backend.allocator.free_pages == 10
    dense = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                          prompt_bucket=32, page_size=8)
    # dense lanes own max_seq stripes: 2 free lanes * 64 / 8 pseudo-pages
    assert dense.free_pages() == 2 * 64 // 8


# ---------------------------------------------------------------------------
# single-replica router == bare engine (bitwise)
# ---------------------------------------------------------------------------

def test_single_replica_router_bit_identical(engine_parts):
    """One replica behind the router runs the same admissions in the same
    order on the same step schedule as a bare engine — greedy AND sampled
    streams (per-(seed, step, lane) keys) must match bit-for-bit."""
    cfg = engine_parts[0]
    bare = run_and_collect(engine_spec(*engine_parts), mixed_traffic(cfg))
    routed = run_and_collect(
        engine_spec(*engine_parts, n_replicas=1, policy="round_robin"),
        mixed_traffic(cfg))
    assert_streams_equal(bare, routed, "1-replica router vs bare engine")

    kw = dict(n=4, temperature=0.8, top_p=0.9)
    bare_s = run_and_collect(engine_spec(*engine_parts, seed=7),
                             mixed_traffic(cfg, **kw))
    routed_s = run_and_collect(
        engine_spec(*engine_parts, seed=7, n_replicas=1,
                    policy="round_robin"),
        mixed_traffic(cfg, **kw))
    assert_streams_equal(bare_s, routed_s, "sampled 1-replica router")


# ---------------------------------------------------------------------------
# replica-count invariance (the acceptance matrix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dense", "paged"])
@pytest.mark.parametrize("policy", ["round_robin", "least_queue"])
def test_replica_count_invariance(engine_parts, backend, policy):
    """Merged greedy token streams for the same request set are identical
    for 1, 2, and 3 replicas: requests are dispatched whole, every
    replica is solo-deterministic, and results merge by uid (permutation-
    free by construction)."""
    ref = _reference(engine_parts, backend)
    for n in (1, 2, 3):
        spec = engine_spec(*engine_parts, n_replicas=n, policy=policy,
                           **_BACKEND_KW[backend])
        out, router = run_and_collect(spec, mixed_traffic(spec["cfg"]),
                                      max_steps=1000, return_engine=True)
        assert_streams_equal(ref, out, f"{backend}/{policy}/{n} replicas")
        # every request was dispatched exactly once, to a real replica
        uids = [u for u, _ in router.dispatch_log]
        assert sorted(uids) == sorted(ref)
        assert all(0 <= r < n for _, r in router.dispatch_log)


# ---------------------------------------------------------------------------
# least_pages admission safety
# ---------------------------------------------------------------------------

def test_least_pages_never_admits_beyond_reservation(engine_parts):
    """least_pages dispatches only to a replica whose allocator can
    reserve the request's worst-case page count at that instant, so the
    dispatched request is admitted on the replica's very next step:
    per-replica queues never carry a deferred request across a step, and
    the streams still match the reference.  Pools here hold ONE
    reservation each (5 pages of 8 + scratch), forcing the policy to
    defer at the router whenever both replicas are occupied."""
    ref = _reference(engine_parts, "dense")
    cfg, params, dsg = engine_parts
    router = Router(cfg, params, dsg, n_replicas=2, policy="least_pages",
                    n_slots=2, max_seq=64, prompt_bucket=32,
                    admission="overlap", cache_backend="paged",
                    page_size=8, cache_tokens=48)
    for r in mixed_traffic(cfg):
        router.submit(r)
    while router._busy():
        before = len(router.dispatch_log)
        router.step()
        # every request dispatched this step was admitted this step —
        # the engine-internal deferral path never ran under least_pages
        for uid, rep in router.dispatch_log[before:]:
            assert router.replicas[rep].queue_depth() == 0, (
                f"request {uid} sat deferred in replica {rep}'s queue")
        assert router.steps < 1000
    out = {u: list(r.output) for u, r in router.done().items()}
    assert_streams_equal(ref, out, "least_pages tiny pools")
    # with single-reservation pools, deferral must actually have happened
    # at the router (6 requests, 2 one-lane-at-a-time replicas)
    assert router.steps > len(router.replicas)


# ---------------------------------------------------------------------------
# decode_chunk axis (harness.CHUNK_AXIS)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", CHUNK_AXIS)
def test_routed_streams_invariant_to_decode_chunk(engine_parts, chunk):
    """The fused decode chunk is a pure batching change: a 2-replica
    router running chunked engines merges the same greedy streams as
    the unchunked bare-engine reference."""
    cfg = engine_parts[0]
    ref = run_and_collect(engine_spec(*engine_parts), mixed_traffic(cfg))
    out = run_and_collect(
        engine_spec(*engine_parts, decode_chunk=chunk, n_replicas=2,
                    policy="round_robin"),
        mixed_traffic(cfg), max_steps=1000)
    assert_streams_equal(ref, out, f"router decode_chunk={chunk}")
