"""Serving-layer tests: logit-DSG correctness/hit-rate and the
continuous-batching engine (sampling, truncation signalling, throughput
accounting)."""
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.dsg_linear import DSGConfig
from repro.core import logit_dsg
from repro.models import api
from repro.serving.scheduler import Request, ServingEngine


# ---------------------------------------------------------------------------
# logit DSG
# ---------------------------------------------------------------------------

def test_dsg_logits_exact_on_selected_blocks():
    key = jax.random.PRNGKey(0)
    d, v, b = 64, 512, 4
    w = jax.random.normal(key, (d, v)) / np.sqrt(d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    cfg = DSGConfig(enabled=True, gamma=0.5, block=32, eps=0.5)
    st = logit_dsg.init_logit_dsg(jax.random.fold_in(key, 2), w, cfg)
    logits, mask = logit_dsg.dsg_logits(x, w, st, cfg)
    full = x @ w
    sel = np.asarray(mask, bool)                  # (B, G) per-request
    lg = np.asarray(logits).reshape(b, -1, 32)
    fg = np.asarray(full).reshape(b, -1, 32)
    np.testing.assert_allclose(lg[sel], fg[sel], rtol=2e-5, atol=2e-5)
    assert (lg[~sel] <= -1e29).all()
    # batch-shared mode still exact on its selection
    lg2, m2 = logit_dsg.dsg_logits(x, w, st, cfg, per_request=False)
    sel2 = np.asarray(m2, bool)
    lg2 = np.asarray(lg2).reshape(b, -1, 32)
    np.testing.assert_allclose(lg2[sel2], fg[sel2], rtol=2e-5, atol=2e-5)


def test_dsg_logits_greedy_hit_rate():
    """The true argmax block should be selected nearly always at gamma=0.5
    when logits carry decode-realistic margin (hidden states correlate
    with the winning vocab column; purely-iid logits have no margin and
    no method can find the max cheaply)."""
    key = jax.random.PRNGKey(3)
    d, v, b = 128, 1024, 64
    w = jax.random.normal(key, (d, v)) / np.sqrt(d)
    targets = jax.random.randint(jax.random.fold_in(key, 9), (b,), 0, v)
    noise = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    x = 2.0 * w[:, targets].T * np.sqrt(d) / jnp.linalg.norm(
        w[:, targets].T, axis=-1, keepdims=True) + noise
    cfg = DSGConfig(enabled=True, gamma=0.5, block=32, eps=0.3)
    st = logit_dsg.init_logit_dsg(jax.random.fold_in(key, 2), w, cfg)
    logits, _ = logit_dsg.dsg_logits(x, w, st, cfg)
    hit = (jnp.argmax(logits, -1) == jnp.argmax(x @ w, -1)).mean()
    assert float(hit) > 0.9
    # FLOP saving at production head dims (toy d=128 caps k at d: the
    # projection cannot compress below the input dim)
    assert logit_dsg.flops_saving(131072, 5120, cfg) > 0.35   # eps=0.3
    assert logit_dsg.flops_saving(
        131072, 5120, cfg._replace(eps=0.5)) > 0.4


# ---------------------------------------------------------------------------
# continuous batching engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_parts():
    cfg = configs.get_smoke_config("internlm2-1.8b")
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)
    return cfg, params, dsg


def test_engine_completes_requests(engine_parts):
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                        prompt_bucket=16)
    rng = np.random.default_rng(0)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab, 12,
                                               dtype=np.int32),
                           max_new=6))
    done = eng.run(max_steps=200)
    assert len(done) == 5
    for r in done.values():
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab for t in r.output)
    assert eng.throughput() > 0


def test_engine_eos_early_stop(engine_parts):
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                        prompt_bucket=16)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    # discover the greedy continuation, then pick as EOS a token whose
    # FIRST occurrence is at position j — greedy decoding often repeats,
    # and a repeated token would (correctly) retire the request at its
    # first occurrence, making the expected stop position ambiguous
    eng.submit(Request(uid=0, prompt=prompt, max_new=4))
    probe = eng.run(max_steps=50)[0].output
    j = next((j for j in range(1, len(probe)) if probe[j] not in probe[:j]),
             None)
    if j is None:
        pytest.skip("degenerate greedy continuation (all tokens equal)")
    eng2 = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                         prompt_bucket=16)
    eng2.submit(Request(uid=1, prompt=prompt, max_new=10,
                        eos_id=probe[j]))
    done = eng2.run(max_steps=100)
    # retirement happens AFTER the EOS token is emitted: the output is the
    # greedy prefix up to and including the first occurrence of eos_id
    assert done[1].output == probe[:j + 1]


def test_paged_shared_mode_deterministic(engine_parts):
    """Paged + the paper's shared-threshold DRS (the smoke default): free
    lanes mirror the donor's page-table row, so row-0 scores driving every
    lane's sparsity mask are real donor statistics, not scratch-page junk
    — two identical runs (with a retirement mid-stream so a mirrored lane
    actually participates) must agree exactly."""
    cfg, params, dsg = engine_parts
    assert cfg.dsg.threshold_mode == "shared"
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (10, 6, 14)]

    def run_once():
        eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                            prompt_bucket=16, cache_backend="paged",
                            page_size=8)
        # max_new 3 vs 9: slot 1 retires and idles while slot 0 decodes
        for uid, (p, m) in enumerate(zip(prompts, (9, 3, 4))):
            eng.submit(Request(uid=uid, prompt=p, max_new=m))
        return {u: r.output for u, r in eng.run(max_steps=200).items()}

    assert run_once() == run_once()


def test_prompt_truncation_flagged_and_warned_once(engine_parts):
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                        prompt_bucket=16)
    rng = np.random.default_rng(4)
    for uid in range(2):     # two over-long prompts, ONE warning
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab, 40,
                                               dtype=np.int32),
                           max_new=3))
    eng.submit(Request(uid=2, prompt=rng.integers(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                       max_new=3))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        done = eng.run(max_steps=100)
    trunc_warns = [w for w in caught if "exceeds the largest bucket"
                   in str(w.message)]
    assert len(trunc_warns) == 1
    assert done[0].truncated and done[1].truncated
    assert not done[2].truncated


def test_sampling_topp_collapse_matches_greedy(engine_parts):
    """temperature > 0 with a vanishing nucleus keeps only the argmax
    token, so the sampled stream must equal the greedy one."""
    cfg, params, dsg = engine_parts
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 12, dtype=np.int32)

    def run_one(**kw):
        eng = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                            prompt_bucket=16)
        eng.submit(Request(uid=0, prompt=prompt, max_new=8, **kw))
        return eng.run(max_steps=100)[0].output

    greedy = run_one()
    assert run_one(temperature=1.0, top_p=1e-6) == greedy
    assert run_one(temperature=1.0, top_p=0.0) == greedy   # degenerate top_p


def test_full_length_prompt_keeps_decode_headroom(engine_parts):
    """prompt_bucket == max_seq must not admit a lane at pos == max_seq:
    the largest bucket is capped one below max_seq so the first decode
    write stays in cache range (the paged page table would otherwise be
    indexed out of bounds)."""
    cfg, params, dsg = engine_parts
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, 64, dtype=np.int32)
    for backend in ("dense", "paged"):
        eng = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                            prompt_bucket=64, cache_backend=backend,
                            page_size=8)
        assert eng.prompt_bucket == 63
        eng.submit(Request(uid=0, prompt=prompt, max_new=4))
        done = eng.run(max_steps=50)
        assert done[0].truncated and len(done[0].output) == 1


def test_sampling_reproducible_across_engines(engine_parts):
    cfg, params, dsg = engine_parts
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab, 10, dtype=np.int32)

    def run_one(seed):
        eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                            prompt_bucket=16, seed=seed)
        eng.submit(Request(uid=0, prompt=prompt, max_new=10,
                           temperature=1.5, top_p=0.95))
        out = eng.run(max_steps=100)[0].output
        assert all(0 <= t < cfg.vocab for t in out)
        return out

    assert run_one(seed=0) == run_one(seed=0)   # same key schedule


def test_stats_raise_before_any_request_finishes(engine_parts):
    """throughput() has no admission->finish window and decode_tok_per_s()
    no emitted tokens before the first request completes — both must
    raise a clear ValueError instead of returning a 0.0 that reads as
    "infinitely slow" in benchmark ratios (the old silent fallback)."""
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                        prompt_bucket=16)
    with pytest.raises(ValueError, match="finished request"):
        eng.throughput()
    with pytest.raises(ValueError, match="decoded token"):
        eng.decode_tok_per_s()
    # still raising after submit (queued work is not finished work) ...
    rng = np.random.default_rng(3)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                       max_new=3))
    with pytest.raises(ValueError, match="finished request"):
        eng.throughput()
    # ... and well-defined as soon as one request retires
    eng.run(max_steps=50)
    assert eng.throughput() > 0.0
    assert eng.decode_tok_per_s() > 0.0


def test_throughput_ignores_pre_run_queue_wait(engine_parts):
    """throughput() spans first admission -> last finish; a request that
    sat in the queue long before run() must not dilute it.  The
    decode-only rate is reported separately."""
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                        prompt_bucket=16)
    rng = np.random.default_rng(8)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                       max_new=5))
    eng.queue[0].submitted = time.perf_counter() - 1_000.0  # stale wait
    done = eng.run(max_steps=100)
    toks = sum(len(r.output) for r in done.values())
    # the old submit->finish span would cap throughput at toks/1000
    assert eng.throughput() > toks / 500.0
    assert eng.decode_tok_per_s() > 0.0
    assert eng.latencies()[0] > 999.0    # latency still counts queue wait
