"""Serving-layer tests: logit-DSG correctness/hit-rate and the
continuous-batching engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.dsg_linear import DSGConfig
from repro.core import logit_dsg
from repro.models import api
from repro.serving.scheduler import Request, ServingEngine


# ---------------------------------------------------------------------------
# logit DSG
# ---------------------------------------------------------------------------

def test_dsg_logits_exact_on_selected_blocks():
    key = jax.random.PRNGKey(0)
    d, v, b = 64, 512, 4
    w = jax.random.normal(key, (d, v)) / np.sqrt(d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    cfg = DSGConfig(enabled=True, gamma=0.5, block=32, eps=0.5)
    st = logit_dsg.init_logit_dsg(jax.random.fold_in(key, 2), w, cfg)
    logits, mask = logit_dsg.dsg_logits(x, w, st, cfg)
    full = x @ w
    sel = np.asarray(mask, bool)                  # (B, G) per-request
    lg = np.asarray(logits).reshape(b, -1, 32)
    fg = np.asarray(full).reshape(b, -1, 32)
    np.testing.assert_allclose(lg[sel], fg[sel], rtol=2e-5, atol=2e-5)
    assert (lg[~sel] <= -1e29).all()
    # batch-shared mode still exact on its selection
    lg2, m2 = logit_dsg.dsg_logits(x, w, st, cfg, per_request=False)
    sel2 = np.asarray(m2, bool)
    lg2 = np.asarray(lg2).reshape(b, -1, 32)
    np.testing.assert_allclose(lg2[sel2], fg[sel2], rtol=2e-5, atol=2e-5)


def test_dsg_logits_greedy_hit_rate():
    """The true argmax block should be selected nearly always at gamma=0.5
    when logits carry decode-realistic margin (hidden states correlate
    with the winning vocab column; purely-iid logits have no margin and
    no method can find the max cheaply)."""
    key = jax.random.PRNGKey(3)
    d, v, b = 128, 1024, 64
    w = jax.random.normal(key, (d, v)) / np.sqrt(d)
    targets = jax.random.randint(jax.random.fold_in(key, 9), (b,), 0, v)
    noise = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    x = 2.0 * w[:, targets].T * np.sqrt(d) / jnp.linalg.norm(
        w[:, targets].T, axis=-1, keepdims=True) + noise
    cfg = DSGConfig(enabled=True, gamma=0.5, block=32, eps=0.3)
    st = logit_dsg.init_logit_dsg(jax.random.fold_in(key, 2), w, cfg)
    logits, _ = logit_dsg.dsg_logits(x, w, st, cfg)
    hit = (jnp.argmax(logits, -1) == jnp.argmax(x @ w, -1)).mean()
    assert float(hit) > 0.9
    # FLOP saving at production head dims (toy d=128 caps k at d: the
    # projection cannot compress below the input dim)
    assert logit_dsg.flops_saving(131072, 5120, cfg) > 0.35   # eps=0.3
    assert logit_dsg.flops_saving(
        131072, 5120, cfg._replace(eps=0.5)) > 0.4


# ---------------------------------------------------------------------------
# continuous batching engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_parts():
    cfg = configs.get_smoke_config("internlm2-1.8b")
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)
    return cfg, params, dsg


def test_engine_completes_requests(engine_parts):
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=2, max_seq=64,
                        prompt_bucket=16)
    rng = np.random.default_rng(0)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab, 12,
                                               dtype=np.int32),
                           max_new=6))
    done = eng.run(max_steps=200)
    assert len(done) == 5
    for r in done.values():
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab for t in r.output)
    assert eng.throughput() > 0


def test_engine_eos_early_stop(engine_parts):
    cfg, params, dsg = engine_parts
    eng = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                        prompt_bucket=16)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    # discover the greedy continuation, then pick as EOS a token whose
    # FIRST occurrence is at position j — greedy decoding often repeats,
    # and a repeated token would (correctly) retire the request at its
    # first occurrence, making the expected stop position ambiguous
    eng.submit(Request(uid=0, prompt=prompt, max_new=4))
    probe = eng.run(max_steps=50)[0].output
    j = next((j for j in range(1, len(probe)) if probe[j] not in probe[:j]),
             None)
    if j is None:
        pytest.skip("degenerate greedy continuation (all tokens equal)")
    eng2 = ServingEngine(cfg, params, dsg, n_slots=1, max_seq=64,
                         prompt_bucket=16)
    eng2.submit(Request(uid=1, prompt=prompt, max_new=10,
                        eos_id=probe[j]))
    done = eng2.run(max_steps=100)
    # retirement happens AFTER the EOS token is emitted: the output is the
    # greedy prefix up to and including the first occurrence of eos_id
    assert done[1].output == probe[:j + 1]
