"""Batched serving with DSG active at inference (paper Appendix C: the
dimension-reduction search stays on-the-fly at decode time).

  PYTHONPATH=src python examples/serve_dsg.py --batch 4 --gen 24
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro import configs                                   # noqa: E402
from repro.launch.serve import generate                     # noqa: E402
from repro.models import api                                # noqa: E402
import jax                                                  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = api.init_model(key, cfg)
    dsg = api.init_dsg(jax.random.fold_in(key, 1), params, cfg)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32))

    for label, d in (("DSG on", dsg), ("DSG off", None)):
        c = cfg if d is not None else cfg.replace(
            dsg=cfg.dsg._replace(enabled=False))
        t0 = time.time()
        toks = generate(c, params, d, prompts, args.gen)
        dt = time.time() - t0
        print(f"{label:8s}: {args.batch}x{args.gen} tokens in {dt:5.2f}s "
              f"({args.batch*args.gen/dt:6.1f} tok/s) "
              f"first={np.asarray(toks[0])[:6]}")
    print("OK (same params; DSG masks applied on-the-fly during decode)")


if __name__ == "__main__":
    main()
