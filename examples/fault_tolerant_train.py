"""Fault-tolerance demo: inject failures mid-training, watch the loop
restore from the last checkpoint and converge to the same step count;
then lose a host and re-plan the mesh (elastic scaling).

  PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro import configs                                   # noqa: E402
from repro.launch.train import train                        # noqa: E402
from repro.runtime.fault_tolerance import FaultInjector     # noqa: E402
from repro.runtime.elastic import plan_after_loss           # noqa: E402


def main():
    cfg = configs.get_smoke_config("internlm2-1.8b").replace(
        n_layers=2, d_model=64, d_ff=256, vocab=256)
    with tempfile.TemporaryDirectory() as d:
        injector = FaultInjector(fail_at=(13, 27))
        _, hist, _ = train(cfg, steps=40, ckpt_dir=d, ckpt_every=10,
                           global_batch=4, seq_len=32, injector=injector)
        print(f"completed {len(hist)} step records across 2 injected "
              f"failures; final loss {hist[-1]['loss']:.4f}")

    plan = plan_after_loss(512 - 16, model=16)
    print(f"elastic re-plan after losing one 16-chip host: "
          f"{plan.data}x{plan.model} mesh on {plan.n_devices} chips "
          f"({plan.dropped} idle)")
    print("OK")


if __name__ == "__main__":
    main()
