"""Quickstart: train a DSG-sparsified transformer end-to-end.

Defaults are CPU-sized (runs in ~2 minutes); `--model 100m` selects a
~100M-parameter config for a real driver run on accelerators.

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --model 100m --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs                                   # noqa: E402
from repro.core.dsg_linear import DSGConfig                 # noqa: E402
from repro.launch.train import train                        # noqa: E402


def model_100m():
    """~100M params: 12L x 768d, GQA 12H/4kv, SwiGLU 3072, 32k vocab."""
    return configs.get_config("internlm2-1.8b").replace(
        n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=3072,
        vocab=32000, d_head=64, dtype="float32",
        dsg=DSGConfig(enabled=True, gamma=0.5, eps=0.5, block=128,
                      threshold_mode="shared", mode="mask", n_chunks=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("tiny", "100m"), default="tiny")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.5)
    args = ap.parse_args()

    if args.model == "100m":
        cfg = model_100m()
    else:
        cfg = configs.get_smoke_config("internlm2-1.8b").replace(
            n_layers=4, d_model=128, d_ff=512, vocab=512)
    cfg = cfg.replace(dsg=cfg.dsg._replace(gamma=args.gamma))

    print(f"training {args.model} model, DSG gamma={cfg.dsg.gamma} "
          f"block={cfg.dsg.block} threshold={cfg.dsg.threshold_mode}")
    _, hist, monitor = train(cfg, steps=args.steps,
                             global_batch=args.batch, seq_len=args.seq,
                             ckpt_dir=None)
    losses = [h["loss"] for h in hist]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over "
          f"{len(losses)} steps ({sum(h['seconds'] for h in hist):.1f}s, "
          f"{len(monitor.flagged)} stragglers)")
    assert losses[-1] < losses[0], "training should reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
