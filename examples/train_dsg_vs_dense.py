"""Paper Fig. 8(b): large-sparse vs small-dense at matched effective MACs.

Trains (i) a dense model, (ii) the same model with DSG at gamma, and
(iii) a smaller dense model whose FFN has ~the same effective MACs as the
DSG model — the paper's comparison showing large-sparse beats small-dense.

  PYTHONPATH=src python examples/train_dsg_vs_dense.py --steps 120
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs                                   # noqa: E402
from repro.launch.train import train                        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--gamma", type=float, default=0.5)
    args = ap.parse_args()

    base = configs.get_smoke_config("internlm2-1.8b").replace(
        n_layers=4, d_model=128, d_ff=512, vocab=512)

    runs = {
        "dense": base.replace(dsg=base.dsg._replace(enabled=False)),
        f"dsg@{args.gamma}": base.replace(
            dsg=base.dsg._replace(gamma=args.gamma)),
        "small-dense (matched MACs)": base.replace(
            d_ff=int(512 * (1 - args.gamma)) // 64 * 64,
            dsg=base.dsg._replace(enabled=False)),
    }
    print(f"{'run':>28} | final loss (mean of last 10)")
    results = {}
    for name, cfg in runs.items():
        _, hist, _ = train(cfg, steps=args.steps, global_batch=8,
                           seq_len=64)
        final = sum(h["loss"] for h in hist[-10:]) / 10
        results[name] = final
        print(f"{name:>28} | {final:.4f}")
    print("\npaper claim: the large-sparse (DSG) model should sit between "
          "dense and the MAC-matched small-dense model in quality.")


if __name__ == "__main__":
    main()
